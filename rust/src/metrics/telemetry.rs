//! Streaming run telemetry: schema-versioned JSONL events.
//!
//! Every event is one JSON object per line with two fixed fields —
//! `"schema"` (the telemetry schema version, see [`SCHEMA_VERSION`])
//! and `"event"` (the event kind) — plus kind-specific payload fields.
//! The experiment scheduler ([`crate::sched`]) streams one file per
//! job into `runs/<grid-id>/events/<job>.jsonl`; the full field tables
//! and the version policy live in `docs/TELEMETRY.md`.
//!
//! Event kinds (schema 1):
//!
//! * `run_started` / `run_finished` — emitted by the scheduler around
//!   one job (one model × method × seed run).
//! * `step` — one optimizer step (emitted by the trainer).
//! * `control_window` — one §3.4 control-window evaluation.
//! * `oom` — a simulated out-of-memory event.
//! * `host_mem` — a real host-memory sample (`--mem-source host`
//!   only; observational, never part of deterministic artifacts).
//! * `epoch` — one epoch summary row (the [`super::EpochRecord`]
//!   fields).
//!
//! The trainer writes through the [`TelemetrySink`] trait so it never
//! depends on where events go; [`JsonlWriter`] is the file sink and
//! [`SharedSink`] the clonable handle the scheduler threads through.
//!
//! Crash safety: the file sink buffers *whole lines* and seals every
//! event with a `crc` field (FNV-1a-64 over the line without `crc`)
//! before buffering it. Buffered lines are written out at a size
//! threshold, on `run_finished`, on [`JsonlWriter::flush`], and on
//! drop — so a killed or panicking job leaves an events file that
//! ends on a complete, verifiable record instead of a torn tail.
//! Writes go through the [`ArtifactIo`] seam, which is how injected
//! IO faults (`docs/FAULTS.md`) reach this sink in tests.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::checkpoint::fnv1a;
use crate::faults::{ArtifactIo, RealIo};
use crate::util::json::Json;

use super::EpochRecord;

/// Telemetry schema version stamped into every event line. Bump only
/// for breaking changes (renamed/retyped fields); adding new fields or
/// new event kinds is backward-compatible and does not bump it.
pub const SCHEMA_VERSION: u64 = 1;

/// Where events go. The trainer emits through this trait; sinks must
/// tolerate being called once per optimizer step.
pub trait TelemetrySink: Send {
    /// Record one event (one JSONL line).
    fn emit(&mut self, event: &Json);
}

fn base(event: &str) -> std::collections::BTreeMap<String, Json> {
    let mut m = std::collections::BTreeMap::new();
    m.insert("schema".to_string(), Json::Num(SCHEMA_VERSION as f64));
    m.insert("event".to_string(), Json::Str(event.to_string()));
    m
}

fn num(m: &mut std::collections::BTreeMap<String, Json>, k: &str, v: f64) {
    m.insert(k.to_string(), Json::Num(v));
}

fn s(m: &mut std::collections::BTreeMap<String, Json>, k: &str, v: &str) {
    m.insert(k.to_string(), Json::Str(v.to_string()));
}

/// `run_started`: the scheduler is about to execute one job.
pub fn ev_run_started(
    job: &str,
    model: &str,
    method_key: &str,
    seed: u64,
    digest: u64,
    config_hash: u64,
) -> Json {
    let mut m = base("run_started");
    s(&mut m, "job", job);
    s(&mut m, "model", model);
    s(&mut m, "method", method_key);
    // Decimal string: u64 seeds past 2^53 would lose bits as a number.
    s(&mut m, "seed", &seed.to_string());
    s(&mut m, "digest", &format!("{digest:016x}"));
    s(&mut m, "config_hash", &format!("{config_hash:016x}"));
    Json::Obj(m)
}

/// `run_finished`: the job completed; carries the persisted per-seed
/// result object (the same JSON stored in `ledger.json`) and the
/// job's wall-clock seconds (informational — wall time is the one
/// field that varies across reruns).
pub fn ev_run_finished(job: &str, result: Json, wall_s: f64) -> Json {
    let mut m = base("run_finished");
    s(&mut m, "job", job);
    m.insert("result".to_string(), result);
    num(&mut m, "wall_s", wall_s);
    Json::Obj(m)
}

/// `step`: one optimizer step — step index, live batch size, training
/// loss, the modeled accelerator-seconds for the step, the live
/// data-parallel replica count (1 for non-replicated runs; replica
/// moves never change the loss trajectory), and the simulator's memory
/// scalars for the step (`used_gb`/`max_gb` — the series the trace
/// recorder extracts, see `docs/MEMORY.md`).
pub fn ev_step(
    step: u64,
    batch: usize,
    loss: f64,
    modeled_s: f64,
    replicas: usize,
    used_gb: f64,
    max_gb: f64,
) -> Json {
    let mut m = base("step");
    num(&mut m, "step", step as f64);
    num(&mut m, "batch", batch as f64);
    num(&mut m, "loss", loss);
    num(&mut m, "modeled_s", modeled_s);
    num(&mut m, "replicas", replicas as f64);
    num(&mut m, "used_gb", used_gb);
    num(&mut m, "max_gb", max_gb);
    Json::Obj(m)
}

/// `host_mem`: a real host-memory sample taken at a control window
/// (`--mem-source host` only). Observational — the sample feeds this
/// event stream only, never policy decisions, digests, goldens, or
/// ledger results; `source` names the meter that produced it.
pub fn ev_host_mem(step: u64, used_gb: f64, max_gb: f64, source: &str) -> Json {
    let mut m = base("host_mem");
    num(&mut m, "step", step as f64);
    num(&mut m, "used_gb", used_gb);
    num(&mut m, "max_gb", max_gb);
    s(&mut m, "source", source);
    Json::Obj(m)
}

/// `oom`: the memory simulator saw usage exceed the live budget at
/// this step (a real static-batch run would have crashed here).
pub fn ev_oom(step: u64, used_gb: f64, max_gb: f64) -> Json {
    let mut m = base("oom");
    num(&mut m, "step", step as f64);
    num(&mut m, "used_gb", used_gb);
    num(&mut m, "max_gb", max_gb);
    Json::Obj(m)
}

/// `control_window`: one §3.4 control-window evaluation — how many
/// curvature promotions fired, the batch size after the window, the
/// live loss scale, and the replica count after the window (the
/// elastic shed/restore decisions surface here).
pub fn ev_control_window(
    step: u64,
    promotions: usize,
    batch: usize,
    loss_scale: f64,
    replicas: usize,
) -> Json {
    let mut m = base("control_window");
    num(&mut m, "step", step as f64);
    num(&mut m, "promotions", promotions as f64);
    num(&mut m, "batch", batch as f64);
    num(&mut m, "loss_scale", loss_scale);
    num(&mut m, "replicas", replicas as f64);
    Json::Obj(m)
}

/// `epoch`: one epoch summary row (every [`EpochRecord`] field).
pub fn ev_epoch(r: &EpochRecord) -> Json {
    let mut m = base("epoch");
    num(&mut m, "epoch", r.epoch as f64);
    num(&mut m, "steps", r.steps as f64);
    num(&mut m, "examples", r.examples as f64);
    num(&mut m, "train_loss", r.train_loss);
    num(&mut m, "train_acc", r.train_acc);
    num(&mut m, "test_loss", r.test_loss);
    num(&mut m, "test_acc", r.test_acc);
    num(&mut m, "wall_s", r.wall_s);
    num(&mut m, "modeled_s", r.modeled_s);
    num(&mut m, "modeled_s_norm", r.modeled_s_norm);
    num(&mut m, "peak_vram_gb", r.peak_vram_gb);
    num(&mut m, "mean_batch", r.mean_batch);
    num(&mut m, "fp16_frac", r.mix.fp16);
    num(&mut m, "bf16_frac", r.mix.bf16);
    num(&mut m, "fp32_frac", r.mix.fp32);
    num(&mut m, "lr", r.lr);
    num(&mut m, "loss_scale", r.loss_scale);
    num(&mut m, "eff_score", r.eff_score);
    Json::Obj(m)
}

/// Seal one event: add a `crc` field — the FNV-1a-64 digest (16-hex)
/// of the event's compact serialization without `crc`. Recomputable
/// exactly by any consumer because [`Json::to_string_compact`] is
/// deterministic. Non-object events pass through unsealed.
fn sealed_line(event: &Json) -> String {
    match event {
        Json::Obj(fields) => {
            let mut m = fields.clone();
            m.remove("crc");
            let unsealed = Json::Obj(m.clone()).to_string_compact();
            m.insert("crc".to_string(), Json::Str(format!("{:016x}", fnv1a(unsealed.as_bytes()))));
            Json::Obj(m).to_string_compact()
        }
        other => other.to_string_compact(),
    }
}

/// Verify a parsed event line's seal: recompute the digest over the
/// object minus `crc` and compare. Objects without `crc` never verify.
pub fn crc_ok(event: &Json) -> bool {
    let (Some(fields), Some(stored)) =
        (event.as_obj(), event.get("crc").and_then(Json::as_str))
    else {
        return false;
    };
    let mut m = fields.clone();
    m.remove("crc");
    let crc = fnv1a(Json::Obj(m).to_string_compact().as_bytes());
    stored == format!("{crc:016x}")
}

/// Write out buffered lines once they exceed this size (small enough
/// to keep the stream observable while a job runs, large enough to
/// amortize the append syscall over many `step` events).
const WRITE_OUT_BYTES: usize = 8 * 1024;

/// JSONL file sink buffering *whole sealed lines*. IO errors are
/// latched and surfaced at [`Self::flush`] (the sink trait has no
/// error channel — the trainer should not abort a run over a
/// telemetry write). Because only complete lines ever reach the file,
/// and the buffer drains on `run_finished`, on `flush`, and on drop,
/// a killed job's events file always ends on a complete record.
pub struct JsonlWriter {
    path: PathBuf,
    io: Arc<dyn ArtifactIo>,
    /// Complete sealed lines not yet written to the file.
    buf: String,
    error: Option<std::io::Error>,
}

impl JsonlWriter {
    /// Create (truncating any previous file — a killed job's partial
    /// event stream is replaced when the job reruns).
    pub fn create(path: &Path) -> Result<JsonlWriter> {
        Self::create_with_io(path, Arc::new(RealIo))
    }

    /// Create with an explicit artifact-IO implementation (the
    /// scheduler passes its fault-injecting seam here).
    pub fn create_with_io(path: &Path, io: Arc<dyn ArtifactIo>) -> Result<JsonlWriter> {
        io.create(path).with_context(|| format!("creating {}", path.display()))?;
        Ok(JsonlWriter { path: path.to_path_buf(), io, buf: String::new(), error: None })
    }

    /// The file this sink writes to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append every buffered line to the file. On failure the error is
    /// latched, the buffer discarded, and later emits are dropped —
    /// the attempt is already doomed; [`Self::flush`] reports it.
    fn write_out(&mut self) {
        if self.error.is_some() || self.buf.is_empty() {
            return;
        }
        if let Err(e) = self.io.append(&self.path, &self.buf) {
            self.error = Some(e);
        }
        self.buf.clear();
    }

    /// Drain the buffer; reports the first latched write error.
    pub fn flush(&mut self) -> Result<()> {
        self.write_out();
        if let Some(e) = self.error.take() {
            return Err(anyhow::anyhow!("telemetry write to {}: {e}", self.path.display()));
        }
        Ok(())
    }
}

impl TelemetrySink for JsonlWriter {
    fn emit(&mut self, event: &Json) {
        if self.error.is_some() {
            return;
        }
        self.buf.push_str(&sealed_line(event));
        self.buf.push('\n');
        let finished = event.get("event").and_then(Json::as_str) == Some("run_finished");
        if finished || self.buf.len() >= WRITE_OUT_BYTES {
            self.write_out();
        }
    }
}

impl Drop for JsonlWriter {
    fn drop(&mut self) {
        // Best effort: a panicking job's sink drops during unwind, and
        // whatever it buffered lands as complete lines.
        self.write_out();
    }
}

/// Clonable handle over a shared [`JsonlWriter`]: the scheduler keeps
/// one clone to emit `run_started`/`run_finished` while the trainer
/// owns another for the inner `step`/`epoch`/`oom`/`control_window`
/// stream.
#[derive(Clone)]
pub struct SharedSink(Arc<Mutex<JsonlWriter>>);

impl SharedSink {
    /// Wrap a writer for shared use.
    pub fn new(w: JsonlWriter) -> SharedSink {
        SharedSink(Arc::new(Mutex::new(w)))
    }

    /// Record one event (lock + write).
    pub fn post(&self, event: &Json) {
        self.0.lock().unwrap().emit(event);
    }

    /// Flush the underlying writer and surface latched write errors.
    pub fn flush(&self) -> Result<()> {
        self.0.lock().unwrap().flush()
    }
}

impl TelemetrySink for SharedSink {
    fn emit(&mut self, event: &Json) {
        self.post(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::PrecisionMix;

    #[test]
    fn events_carry_schema_and_kind() {
        let ev = ev_step(7, 64, 2.5, 0.001, 2, 0.3, 0.5);
        assert_eq!(ev.get("schema").unwrap().as_i64(), Some(SCHEMA_VERSION as i64));
        assert_eq!(ev.get("event").unwrap().as_str(), Some("step"));
        assert_eq!(ev.get("batch").unwrap().as_usize(), Some(64));
        assert_eq!(ev.get("replicas").unwrap().as_usize(), Some(2));
        let ev = ev_oom(3, 0.5, 0.4);
        assert_eq!(ev.get("event").unwrap().as_str(), Some("oom"));
        let ev = ev_control_window(9, 2, 96, 1024.0, 2);
        assert_eq!(ev.get("promotions").unwrap().as_usize(), Some(2));
        assert_eq!(ev.get("replicas").unwrap().as_usize(), Some(2));
        let ev = ev_run_started("j", "m", "tri_accel", 1, 0xAB, 0xCD);
        assert_eq!(ev.get("digest").unwrap().as_str(), Some("00000000000000ab"));
    }

    #[test]
    fn epoch_event_mirrors_record() {
        let r = EpochRecord {
            epoch: 1,
            steps: 10,
            train_loss: 1.0,
            train_acc: 50.0,
            test_loss: 1.1,
            test_acc: 49.0,
            examples: 640,
            wall_s: 0.5,
            modeled_s: 0.05,
            modeled_s_norm: 0.4,
            peak_vram_gb: 0.3,
            mean_batch: 64.0,
            mix: PrecisionMix { fp16: 0.25, bf16: 0.5, fp32: 0.25 },
            lr: 0.1,
            loss_scale: 1024.0,
            eff_score: 12.0,
        };
        let ev = ev_epoch(&r);
        assert_eq!(ev.get("event").unwrap().as_str(), Some("epoch"));
        assert_eq!(ev.get("epoch").unwrap().as_usize(), Some(1));
        assert_eq!(ev.get("bf16_frac").unwrap().as_f64(), Some(0.5));
        assert_eq!(ev.get("eff_score").unwrap().as_f64(), Some(12.0));
    }

    #[test]
    fn jsonl_writer_streams_lines() {
        let dir = std::env::temp_dir().join(format!("triaccel_tel_{}", std::process::id()));
        let path = dir.join("events.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        w.emit(&ev_step(0, 32, 2.0, 0.001, 1, 0.2, 0.5));
        w.emit(&ev_step(1, 32, 1.9, 0.001, 1, 0.2, 0.5));
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            let j = Json::parse(l).unwrap();
            assert_eq!(j.get("event").unwrap().as_str(), Some("step"));
            assert!(crc_ok(&j), "every written line is sealed: {l}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crc_seal_detects_tampering() {
        let line = sealed_line(&ev_step(3, 64, 1.5, 0.002, 1, 0.2, 0.5));
        let j = Json::parse(&line).unwrap();
        assert!(crc_ok(&j));
        let tampered = line.replace("\"batch\":64", "\"batch\":65");
        assert_ne!(tampered, line);
        assert!(!crc_ok(&Json::parse(&tampered).unwrap()), "flipped field must fail the seal");
        assert!(!crc_ok(&ev_step(3, 64, 1.5, 0.002, 1, 0.2, 0.5)), "unsealed event never verifies");
    }

    #[test]
    fn buffer_drains_on_run_finished_and_on_drop() {
        let dir = std::env::temp_dir().join(format!("triaccel_teld_{}", std::process::id()));
        let path = dir.join("drain.jsonl");
        let mut w = JsonlWriter::create(&path).unwrap();
        w.emit(&ev_step(0, 32, 2.0, 0.001, 1, 0.2, 0.5));
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "",
            "small events stay buffered"
        );
        w.emit(&ev_run_finished("j", Json::Null, 0.1));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2, "run_finished drains the buffer");
        assert!(text.ends_with('\n'), "file ends on a complete record");
        w.emit(&ev_step(1, 32, 1.9, 0.001, 1, 0.2, 0.5));
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3, "drop drains the buffered tail");
        assert!(text.ends_with('\n'));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_sink_clones_write_one_stream() {
        let dir = std::env::temp_dir().join(format!("triaccel_tels_{}", std::process::id()));
        let path = dir.join("shared.jsonl");
        let sink = SharedSink::new(JsonlWriter::create(&path).unwrap());
        let mut clone: Box<dyn TelemetrySink> = Box::new(sink.clone());
        sink.post(&ev_run_started("j", "m", "k", 0, 1, 2));
        clone.emit(&ev_step(0, 16, 2.0, 0.001, 1, 0.2, 0.5));
        sink.post(&ev_run_finished("j", Json::Null, 0.1));
        sink.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().next().unwrap().contains("run_started"));
        assert!(text.lines().last().unwrap().contains("run_finished"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
