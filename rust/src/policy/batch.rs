//! §3.3 Memory-Elastic Batch Scaling.
//!
//! The paper's VRAM feedback controller:
//!
//! ```text
//! B(t+1) = B(t) + δ↑   if MemUsage(t) < ρ_low · MemMax
//!          B(t) − δ↓   if MemUsage(t) > ρ_high · MemMax
//!          B(t)        otherwise
//! ```
//!
//! Two adaptations to the AOT substrate (DESIGN.md decision 2): PJRT
//! executables are shape-specialized, so B(t) moves along the bucket
//! ladder baked at compile time (δ↑/δ↓ become "one bucket"), and growth
//! is vetoed by a predictive `would_fit` check so the controller never
//! *causes* the OOM it exists to avoid. A cooldown between moves damps
//! oscillation from allocator noise.
//!
//! Two [`BatchPolicy`](super::BatchPolicy) impls live here:
//! [`BatchController`] (the feedback rule above) and [`FixedBatch`]
//! (the static baselines — B snapped to the ladder once, then held;
//! a real run at that size would simply OOM under pressure).

use super::{ckpt_lookup, BatchPolicy};

/// Outcome of one controller decision (telemetry / tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMove {
    Grow,
    Shrink,
    Hold,
    /// Growth was indicated but vetoed by the fit predictor.
    VetoedGrow,
}

#[derive(Debug, Clone)]
pub struct BatchConfig {
    pub rho_low: f64,
    pub rho_high: f64,
    /// Minimum steps between moves.
    pub cooldown: u64,
}

impl BatchConfig {
    pub fn from_cfg(cfg: &crate::config::Config) -> BatchConfig {
        BatchConfig {
            rho_low: cfg.rho_low,
            rho_high: cfg.rho_high,
            cooldown: cfg.batch_cooldown,
        }
    }
}

/// Snap `init` onto the ascending ladder: largest bucket ≤ init, else
/// the smallest bucket. Shared by both batch policies so the static
/// baselines and the elastic controller start at the same B.
fn snap(buckets: &mut Vec<usize>, init: usize) -> usize {
    assert!(!buckets.is_empty(), "no train buckets");
    buckets.sort_unstable();
    buckets.dedup();
    buckets.iter().rposition(|&b| b <= init).unwrap_or(0)
}

pub struct BatchController {
    cfg: BatchConfig,
    /// Ascending AOT bucket ladder.
    buckets: Vec<usize>,
    /// Index into `buckets`.
    idx: usize,
    last_move_step: u64,
    moves: u64,
    vetoes: u64,
}

impl BatchController {
    /// `buckets` must be the model's AOT train buckets; `init` snaps to
    /// the nearest bucket ≤ init (paper's initial batch size 96).
    pub fn new(mut buckets: Vec<usize>, init: usize, cfg: BatchConfig) -> BatchController {
        let idx = snap(&mut buckets, init);
        BatchController { cfg, buckets, idx, last_move_step: 0, moves: 0, vetoes: 0 }
    }

    pub fn current(&self) -> usize {
        self.buckets[self.idx]
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// One §3.3 decision. `mem_used`/`mem_max` in GiB; `step` for the
    /// cooldown; `fits(next_b)` is the predictive OOM veto over the
    /// candidate batch size (from `VramSim::would_fit`).
    pub fn update<F: FnMut(usize) -> bool>(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        mut fits: F,
    ) -> BatchMove {
        let frac = mem_used / mem_max;
        // OOM-pressure shrink bypasses the cooldown: reacting late to
        // over-budget usage defeats the controller's purpose.
        if frac > self.cfg.rho_high {
            if self.idx > 0 {
                self.idx -= 1;
                self.last_move_step = step;
                self.moves += 1;
                return BatchMove::Shrink;
            }
            return BatchMove::Hold; // already at the smallest bucket
        }
        if step.saturating_sub(self.last_move_step) < self.cfg.cooldown {
            return BatchMove::Hold;
        }
        if frac < self.cfg.rho_low && self.idx + 1 < self.buckets.len() {
            let next = self.buckets[self.idx + 1];
            if fits(next) {
                self.idx += 1;
                self.last_move_step = step;
                self.moves += 1;
                return BatchMove::Grow;
            }
            self.vetoes += 1;
            return BatchMove::VetoedGrow;
        }
        BatchMove::Hold
    }

    /// Emergency shrink on an actual OOM signal (simulator over-budget or
    /// a real allocator failure): drop one bucket immediately.
    pub fn force_shrink(&mut self, step: u64) -> bool {
        if self.idx == 0 {
            return false;
        }
        self.idx -= 1;
        self.last_move_step = step;
        self.moves += 1;
        true
    }

    pub fn moves(&self) -> u64 {
        self.moves
    }

    pub fn vetoes(&self) -> u64 {
        self.vetoes
    }

    /// Serialize (current bucket *value*, cooldown anchor, move/veto
    /// counters). The value — not the ladder index — is stored so a
    /// checkpoint resumed under a backend with a different bucket
    /// ladder fails loudly instead of silently landing on a different
    /// batch size.
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        vec![(
            "policy/batch.elastic/state".into(),
            vec![
                self.current() as f64,
                self.last_move_step as f64,
                self.moves as f64,
                self.vetoes as f64,
            ],
        )]
    }

    /// Restore state written by [`Self::export_state`] (or the legacy
    /// `batch/state` key of pre-policy checkpoints).
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        let v = ckpt_lookup(kv, &["policy/batch.elastic/state", "batch/state"])?;
        anyhow::ensure!(v.len() == 4, "batch state arity");
        let bucket = v[0] as usize;
        let idx = self
            .buckets
            .iter()
            .position(|&b| b == bucket)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint batch size {bucket} is not on this ladder {:?}",
                    self.buckets
                )
            })?;
        self.idx = idx;
        self.last_move_step = v[1] as u64;
        self.moves = v[2] as u64;
        self.vetoes = v[3] as u64;
        Ok(())
    }
}

impl BatchPolicy for BatchController {
    fn name(&self) -> &'static str {
        "batch.elastic"
    }

    fn elastic(&self) -> bool {
        true
    }

    fn update(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        fits: &mut dyn FnMut(usize) -> bool,
    ) -> BatchMove {
        BatchController::update(self, step, mem_used, mem_max, |b| fits(b))
    }

    fn force_shrink(&mut self, step: u64) -> bool {
        BatchController::force_shrink(self, step)
    }

    fn current(&self) -> usize {
        BatchController::current(self)
    }

    fn decisions(&self) -> u64 {
        self.moves + self.vetoes
    }

    fn ladder(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        BatchController::export_state(self)
    }

    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        BatchController::import_state(self, kv)
    }
}

/// Static batch: snapped onto the ladder once, then held regardless of
/// memory pressure — the paper's baselines, which keep B fixed and
/// simply OOM. Stateless (B is derived from config + ladder), so it
/// exports nothing and ignores any batch state a checkpoint carries
/// (matching the pre-policy controller, which skipped the batch import
/// when the elastic path was off).
pub struct FixedBatch {
    b: usize,
}

impl FixedBatch {
    pub fn new(mut buckets: Vec<usize>, init: usize) -> FixedBatch {
        let idx = snap(&mut buckets, init);
        FixedBatch { b: buckets[idx] }
    }
}

impl BatchPolicy for FixedBatch {
    fn name(&self) -> &'static str {
        "batch.fixed"
    }

    fn elastic(&self) -> bool {
        false
    }

    fn update(
        &mut self,
        _step: u64,
        _mem_used: f64,
        _mem_max: f64,
        _fits: &mut dyn FnMut(usize) -> bool,
    ) -> BatchMove {
        BatchMove::Hold
    }

    fn force_shrink(&mut self, _step: u64) -> bool {
        false
    }

    fn current(&self) -> usize {
        self.b
    }

    fn decisions(&self) -> u64 {
        0
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        Vec::new()
    }

    fn import_state(&mut self, _kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatchConfig {
        BatchConfig { rho_low: 0.7, rho_high: 0.9, cooldown: 5 }
    }

    fn ctl() -> BatchController {
        BatchController::new(vec![16, 32, 64, 96, 128], 96, cfg())
    }

    #[test]
    fn init_snaps_to_ladder() {
        assert_eq!(ctl().current(), 96);
        let c = BatchController::new(vec![16, 32, 64], 96, cfg());
        assert_eq!(c.current(), 64, "snap down to largest ≤ init");
        let c = BatchController::new(vec![32, 64], 8, cfg());
        assert_eq!(c.current(), 32, "init below ladder → smallest bucket");
    }

    #[test]
    fn grows_when_underutilized() {
        let mut c = ctl();
        let m = c.update(10, 0.5, 1.0, |_| true);
        assert_eq!(m, BatchMove::Grow);
        assert_eq!(c.current(), 128);
    }

    #[test]
    fn shrinks_when_over_rho_high() {
        let mut c = ctl();
        let m = c.update(10, 0.95, 1.0, |_| true);
        assert_eq!(m, BatchMove::Shrink);
        assert_eq!(c.current(), 64);
    }

    #[test]
    fn holds_in_the_band() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.8, 1.0, |_| true), BatchMove::Hold);
        assert_eq!(c.current(), 96);
    }

    #[test]
    fn cooldown_blocks_consecutive_growth() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.1, 1.0, |_| true), BatchMove::Grow);
        assert_eq!(c.update(12, 0.1, 1.0, |_| true), BatchMove::Hold, "cooling down");
        // 128 is the top bucket, so even after cooldown it's a hold.
        assert_eq!(c.update(20, 0.1, 1.0, |_| true), BatchMove::Hold);
        assert_eq!(c.current(), 128);
    }

    #[test]
    fn shrink_bypasses_cooldown() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.5, 1.0, |_| true), BatchMove::Grow);
        assert_eq!(c.update(11, 0.99, 1.0, |_| true), BatchMove::Shrink);
        assert_eq!(c.current(), 96);
    }

    #[test]
    fn veto_blocks_unfit_growth() {
        let mut c = ctl();
        assert_eq!(c.update(10, 0.5, 1.0, |_| false), BatchMove::VetoedGrow);
        assert_eq!(c.current(), 96);
        assert_eq!(c.vetoes(), 1);
    }

    #[test]
    fn clamps_at_ladder_ends() {
        let mut c = BatchController::new(vec![16, 32], 16, cfg());
        assert_eq!(c.update(10, 0.99, 1.0, |_| true), BatchMove::Hold, "floor");
        c.update(20, 0.1, 1.0, |_| true);
        assert_eq!(c.current(), 32);
        assert_eq!(c.update(40, 0.1, 1.0, |_| true), BatchMove::Hold, "ceiling");
    }

    #[test]
    fn force_shrink_drops_one_bucket() {
        let mut c = ctl();
        assert!(c.force_shrink(5));
        assert_eq!(c.current(), 64);
        c.force_shrink(6);
        c.force_shrink(7);
        c.force_shrink(8);
        assert_eq!(c.current(), 16);
        assert!(!c.force_shrink(9), "cannot shrink below the floor");
    }

    #[test]
    fn ladder_deduped_and_sorted() {
        let c = BatchController::new(vec![96, 16, 96, 32], 96, cfg());
        assert_eq!(c.buckets(), &[16, 32, 96]);
    }

    #[test]
    fn fixed_batch_snaps_like_elastic_and_holds() {
        let mut f = FixedBatch::new(vec![16, 32, 64], 96);
        assert_eq!(BatchPolicy::current(&f), 64, "same snap as the controller");
        let mut fits = |_: usize| true;
        assert_eq!(f.update(10, 0.1, 1.0, &mut fits), BatchMove::Hold);
        assert_eq!(f.update(20, 2.0, 1.0, &mut fits), BatchMove::Hold);
        assert!(!BatchPolicy::force_shrink(&mut f, 5));
        assert_eq!(BatchPolicy::current(&f), 64);
        assert!(BatchPolicy::export_state(&f).is_empty());
        f.import_state(&[("batch/state".into(), vec![32.0, 0.0, 0.0, 0.0])]).unwrap();
        assert_eq!(BatchPolicy::current(&f), 64, "checkpoint batch state ignored");
    }

    #[test]
    fn elastic_state_roundtrips_with_legacy_keys() {
        let mut c = ctl();
        c.update(10, 0.5, 1.0, |_| true);
        c.update(17, 0.5, 1.0, |_| false);
        let saved = BatchController::export_state(&c);
        assert_eq!(saved[0].0, "policy/batch.elastic/state");
        let legacy = vec![("batch/state".to_string(), saved[0].1.clone())];
        for kv in [&saved, &legacy] {
            let mut fresh = ctl();
            fresh.import_state(kv).unwrap();
            assert_eq!(fresh.current(), c.current());
            assert_eq!(fresh.moves(), c.moves());
            assert_eq!(fresh.vetoes(), c.vetoes());
        }
    }
}
