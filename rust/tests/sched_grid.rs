//! Experiment-scheduler integration: the resumable-grid property suite.
//!
//! The contracts under test (ISSUE 5 acceptance criteria):
//! * a grid killed after k of n jobs — for every k — and then resumed
//!   produces `table1.md` and `BENCH_grid.json` byte-identical to an
//!   uninterrupted run;
//! * `--jobs 1` and `--jobs 4` produce byte-identical artifacts over a
//!   2-model × 2-method × 2-seed smoke grid;
//! * the telemetry JSONL stream is schema-versioned, well-formed, and
//!   complete enough to reconstruct the adaptive-behaviour figure.

use std::path::{Path, PathBuf};

use tri_accel::config::{Config, Method};
use tri_accel::policy::registry;
use tri_accel::sched::{self, CellSpec, GridKind, GridSpec, SchedOptions};
use tri_accel::util::json::Json;

fn tweak(cfg: &mut Config) {
    cfg.steps_per_epoch = Some(2);
    cfg.epochs = 1;
    cfg.train_examples = 256;
    cfg.eval_examples = 128;
    cfg.batch_init = 32;
    cfg.t_ctrl = 2;
    cfg.t_curv = 3;
    cfg.curv_warmup = 1;
    cfg.batch_cooldown = 2;
    cfg.warmup_epochs = 0;
    cfg.mem_budget_gb = 0.0;
    cfg.mem_noise = 0.0;
}

/// 2 models × 2 methods × 2 seeds = 8 jobs.
fn smoke_spec() -> GridSpec {
    let mut cells = Vec::new();
    for model in ["tiny_cnn_c10", "tiny_cnn_c100"] {
        for method in [Method::Fp32, Method::TriAccel] {
            let mut base = Config::cell(model, method, 0);
            tweak(&mut base);
            cells.push(CellSpec {
                model_key: model.to_string(),
                label: method.name().to_string(),
                method_key: registry::effective_key(&base),
                seeds: vec![0, 1],
                base,
            });
        }
    }
    GridSpec { kind: GridKind::Table1, cells }
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "triaccel_sched_{name}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn opts(out: &Path, jobs: usize) -> SchedOptions {
    SchedOptions {
        jobs,
        total_threads: 4,
        out_dir: out.to_path_buf(),
        quiet: true,
        ..SchedOptions::default()
    }
}

fn read(p: &Path) -> String {
    std::fs::read_to_string(p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

#[test]
fn jobs1_and_jobs4_grids_are_bit_identical() {
    let spec = smoke_spec();
    let out1 = tmp("j1");
    let out4 = tmp("j4");
    let o1 = sched::run_grid(&spec, &opts(&out1, 1)).unwrap();
    let o4 = sched::run_grid(&spec, &opts(&out4, 4)).unwrap();
    assert!(o1.complete && o4.complete);
    assert_eq!(o1.grid_id, o4.grid_id, "grid id is content-derived, not width-derived");
    assert_eq!(o1.total, 8);
    assert_eq!(o1.executed, 8);
    assert_eq!(
        read(&o1.grid_dir.join("table1.md")),
        read(&o4.grid_dir.join("table1.md")),
        "table1.md must not depend on job-pool width"
    );
    assert_eq!(
        read(&o1.grid_dir.join("BENCH_grid.json")),
        read(&o4.grid_dir.join("BENCH_grid.json")),
        "BENCH_grid.json must not depend on job-pool width"
    );
    // Aggregates re-read from the two ledgers agree bit-for-bit too.
    assert_eq!(o1.cells.len(), o4.cells.len());
    for (a, b) in o1.cells.iter().zip(o4.cells.iter()) {
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(&out1).ok();
    std::fs::remove_dir_all(&out4).ok();
}

#[test]
fn killed_grid_resumes_bit_identically_for_every_k() {
    let spec = smoke_spec();
    let ref_out = tmp("ref");
    let reference = sched::run_grid(&spec, &opts(&ref_out, 1)).unwrap();
    assert!(reference.complete);
    let n = reference.total;
    assert_eq!(n, 8);
    let ref_table = read(&reference.grid_dir.join("table1.md"));
    let ref_bench = read(&reference.grid_dir.join("BENCH_grid.json"));
    assert!(ref_table.contains("| tiny_cnn_c10 |"), "{ref_table}");

    for k in 0..n {
        let out = tmp(&format!("k{k}"));
        // "Kill" after k jobs: the scheduler stops with the ledger
        // recording exactly those completions.
        let mut partial_opts = opts(&out, 2);
        partial_opts.job_limit = Some(k);
        let partial = sched::run_grid(&spec, &partial_opts).unwrap();
        assert_eq!(partial.executed, k, "k={k}");
        assert!(!partial.complete, "k={k}");
        assert!(partial.artifacts.is_empty(), "incomplete grids render nothing");
        assert!(partial.cells.is_empty());

        // Resume at a different job width; only the missing jobs run.
        let resumed = sched::run_grid(&spec, &opts(&out, 4)).unwrap();
        assert!(resumed.complete, "k={k}");
        assert_eq!(resumed.reused, k, "k={k}");
        assert_eq!(resumed.executed, n - k, "k={k}");
        assert_eq!(
            read(&resumed.grid_dir.join("table1.md")),
            ref_table,
            "resumed table1.md diverged at k={k}"
        );
        assert_eq!(
            read(&resumed.grid_dir.join("BENCH_grid.json")),
            ref_bench,
            "resumed BENCH_grid.json diverged at k={k}"
        );
        std::fs::remove_dir_all(&out).ok();
    }

    // A no-op rerun of a complete grid reuses everything and
    // re-renders identical artifacts.
    let rerun = sched::run_grid(&spec, &opts(&ref_out, 2)).unwrap();
    assert_eq!(rerun.executed, 0);
    assert_eq!(rerun.reused, n);
    assert_eq!(read(&rerun.grid_dir.join("table1.md")), ref_table);
    std::fs::remove_dir_all(&ref_out).ok();
}

#[test]
fn pressure_grid_persists_and_renders() {
    let out = tmp("press");
    let spec = sched::pressure_spec(
        "tiny_cnn_c10",
        &["amp_dynamic", "greedy_batch"],
        &[0],
        "ramp:1:3:0.55",
        &tweak,
    )
    .unwrap();
    let o = sched::run_grid(&spec, &opts(&out, 2)).unwrap();
    assert!(o.complete);
    assert_eq!(o.total, 2);
    let md = read(&o.grid_dir.join("pressure.md"));
    assert!(md.contains("ramp:1:3:0.55"), "{md}");
    assert!(md.contains("AMP (Dynamic)") && md.contains("Greedy Batch"), "{md}");
    // Rendering is idempotent: a second pass writes identical bytes.
    let led = sched::Ledger::load(&o.grid_dir.join("ledger.json")).unwrap();
    let bench_before = read(&o.grid_dir.join("BENCH_grid.json"));
    sched::report::render(&o.grid_dir, &led).unwrap();
    assert_eq!(read(&o.grid_dir.join("pressure.md")), md);
    assert_eq!(read(&o.grid_dir.join("BENCH_grid.json")), bench_before);
    std::fs::remove_dir_all(&out).ok();
}

#[test]
fn telemetry_stream_is_schema_versioned_and_reconstructs_fig() {
    let out = tmp("fig");
    let spec = sched::fig_spec("tiny_cnn_c10", 0, &tweak);
    let o = sched::run_grid(&spec, &opts(&out, 1)).unwrap();
    assert!(o.complete);
    let led = sched::Ledger::load(&o.grid_dir.join("ledger.json")).unwrap();
    let key = &led.cells[0].job_keys[0];
    let text = read(&o.grid_dir.join("events").join(format!("{key}.jsonl")));
    let events: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert!(events.len() >= 4, "run_started + 2 steps + epoch + run_finished");
    for ev in &events {
        assert_eq!(ev.req("schema").unwrap().as_i64(), Some(1), "schema-versioned");
        assert!(ev.req("event").unwrap().as_str().is_some());
    }
    assert_eq!(events.first().unwrap().get("event").unwrap().as_str(), Some("run_started"));
    assert_eq!(events.last().unwrap().get("event").unwrap().as_str(), Some("run_finished"));
    let steps = events
        .iter()
        .filter(|e| e.get("event").unwrap().as_str() == Some("step"))
        .count();
    assert_eq!(steps, 2, "one step event per optimizer step");
    let epochs = events
        .iter()
        .filter(|e| e.get("event").unwrap().as_str() == Some("epoch"))
        .count();
    assert_eq!(epochs, 1);
    // The run_finished result matches the ledger entry bit-for-bit.
    let finished = events.last().unwrap().req("result").unwrap();
    let entry = led.entries.get(key).unwrap();
    assert_eq!(
        finished.to_string_compact(),
        entry.result.to_json().to_string_compact()
    );
    // And the figure series reconstruct from telemetry alone.
    let series = sched::report::fig_series(&o.grid_dir, &led).unwrap();
    assert_eq!(series.epoch_eff.len(), 1);
    assert_eq!(series.mix_trace.len(), 1);
    assert!(!series.batch_trace.is_empty());
    assert_eq!(series.batch_trace[0].1, 32, "initial batch from the step events");
    std::fs::remove_dir_all(&out).ok();
}
