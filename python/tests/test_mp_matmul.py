"""mp_matmul Pallas kernel vs oracle: tiling, padding, precision, grads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.mp_matmul import mp_matmul

CODES = [ref.FP16, ref.BF16, ref.FP32]


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@pytest.mark.parametrize("code", CODES)
@pytest.mark.parametrize(
    "mkn",
    [
        (4, 8, 4),  # single tiny block
        (128, 128, 128),  # exactly one full tile
        (130, 257, 65),  # padding on every axis
        (256, 384, 128),  # multi-tile M and N
        (1, 512, 10),  # CIFAR classifier head shape (batch 1)
        (96, 512, 100),  # CIFAR-100 head at paper's initial batch size
    ],
)
def test_mp_matmul_matches_ref(code, mkn):
    m, k, n = mkn
    x = _rand((m, k), seed=hash((code, mkn)) % 2**31)
    w = _rand((k, n), seed=hash((code, mkn, 1)) % 2**31)
    got = mp_matmul(x, w, jnp.int32(code))
    want = ref.mp_matmul_ref(x, w, code)
    # Tile-wise fp32 accumulation reorders sums vs the single-dot oracle:
    # tolerance covers K·eps·‖x‖‖w‖ cancellation noise, not format error.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=5e-4)


def test_multi_k_tile_accumulates_fp32():
    # K spans several tiles; fp32 accumulation must hold even in fp16 mode.
    m, k, n = 32, 512, 32
    x = _rand((m, k), seed=7, scale=0.1)
    w = _rand((k, n), seed=8, scale=0.1)
    got = mp_matmul(x, w, jnp.int32(ref.FP16))
    want = ref.mp_matmul_ref(x, w, ref.FP16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fp32_matches_plain_matmul():
    x, w = _rand((64, 96), seed=9), _rand((96, 48), seed=10)
    got = mp_matmul(x, w, jnp.int32(ref.FP32))
    want = jnp.matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_fp16_loses_precision_vs_fp32():
    # Sanity: the emulation is actually doing something.
    x, w = _rand((64, 64), seed=11), _rand((64, 64), seed=12)
    out16 = np.asarray(mp_matmul(x, w, jnp.int32(ref.FP16)))
    out32 = np.asarray(mp_matmul(x, w, jnp.int32(ref.FP32)))
    assert not np.array_equal(out16, out32)


@pytest.mark.parametrize("code", CODES)
def test_mp_matmul_grads_match_ref(code):
    x, w = _rand((16, 24), seed=13), _rand((24, 8), seed=14)
    t = _rand((16, 8), seed=15)

    def loss_k(x, w):
        return jnp.sum((mp_matmul(x, w, jnp.int32(code)) - t) ** 2)

    def loss_r(x, w):
        y = ref.mp_matmul_ref(x, w, code)
        return jnp.sum((y - t) ** 2)

    gx_k, gw_k = jax.grad(loss_k, argnums=(0, 1))(x, w)
    # Reference backward per our AMP semantics: grad matmuls in `code`.
    g = 2 * (ref.mp_matmul_ref(x, w, code) - t)
    gx_r = ref.mp_matmul_ref(g, w.T, code)
    gw_r = ref.mp_matmul_ref(x.T, g, code)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r), rtol=1e-5, atol=1e-5)
    del loss_r


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 140),
    k=st.integers(1, 140),
    n=st.integers(1, 140),
    code=st.sampled_from(CODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_mp_matmul_hypothesis(m, k, n, code, seed):
    x = _rand((m, k), seed=seed)
    w = _rand((k, n), seed=seed + 1)
    got = mp_matmul(x, w, jnp.int32(code))
    want = ref.mp_matmul_ref(x, w, code)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_mp_matmul_under_jit_code_is_runtime_input():
    # One jitted callable, three precision behaviours — the no-recompile trick.
    x, w = _rand((32, 32), seed=16), _rand((32, 32), seed=17)
    f = jax.jit(lambda x, w, c: mp_matmul(x, w, c))
    outs = [np.asarray(f(x, w, jnp.int32(c))) for c in CODES]
    for c, got in zip(CODES, outs):
        want = np.asarray(ref.mp_matmul_ref(x, w, c))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert not np.array_equal(outs[0], outs[2])
