"""Model-building micro-framework (flax-lite, build-time only).

Every model is a pure function over an ordered, *flat* list of parameter
arrays + a flat list of batchnorm-state arrays — flatness is the contract
with the Rust runtime, which packs/unpacks PJRT literals positionally from
the manifest.

Precision layers: each conv / dense call consumes one entry of the runtime
`codes` i32[L] vector (the paper's per-layer `p_l(t)`), quantizing its
weights and input activations through the L1 `qdq` kernel (dense layers go
through the tiled `mp_matmul` kernel instead). BN parameters stay fp32,
matching AMP practice.

The same forward code runs in three modes via `Store`:
  * init  — allocates params/state, records `LayerSpec`s (param/activation
            element counts that feed the Rust memsim),
  * train — consumes params, emits updated BN state,
  * eval  — consumes params, uses running BN stats.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import api

BN_MOMENTUM = 0.1  # torch-style: running ← (1-m)·running + m·batch
BN_EPS = 1e-5


@dataclasses.dataclass
class LayerSpec:
    """Static accounting for one precision layer (consumed by memsim)."""

    name: str
    kind: str  # "conv" | "dense" | "dwconv"
    param_elems: int  # quantizable weight elements (bias/BN excluded)
    act_elems: int  # output activation elements per sample
    flops: int  # MACs per sample (for the analytic speed model)


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: tuple
    layer_idx: int  # precision layer this param belongs to; -1 = fp32-only


class Store:
    """Positional parameter/state store with three modes (init/train/eval)."""

    def __init__(self, params=None, state=None, rng=None, train=True):
        self.initializing = params is None
        self.params = [] if self.initializing else list(params)
        self.state_in = [] if state is None else list(state)
        self.state_out = []
        self.param_specs: list[ParamSpec] = []
        self.layer_specs: list[LayerSpec] = []
        self._p = 0
        self._s = 0
        self._rng = rng
        self.train = train
        self.codes = None  # set by Model.apply
        self._layer = 0

    # -- precision-layer bookkeeping ------------------------------------
    def next_code(self):
        c = self._layer
        self._layer += 1
        if self.initializing:
            return jnp.int32(api.FP32)
        return self.codes[c]

    @property
    def current_layer(self) -> int:
        return self._layer - 1

    def add_layer_spec(self, spec: LayerSpec):
        if self.initializing:
            self.layer_specs.append(spec)

    # -- params ----------------------------------------------------------
    def param(self, name: str, shape, init_fn: Callable, layer_idx: int = -1):
        if self.initializing:
            self._rng, sub = jax.random.split(self._rng)
            p = init_fn(sub, shape).astype(jnp.float32)
            self.params.append(p)
            self.param_specs.append(ParamSpec(name, tuple(shape), layer_idx))
            return p
        p = self.params[self._p]
        self._p += 1
        return p

    # -- batchnorm state ---------------------------------------------------
    def bn_state(self, shape):
        """Returns (running_mean, running_var); caller pushes updates."""
        if self.initializing:
            rm = jnp.zeros(shape, jnp.float32)
            rv = jnp.ones(shape, jnp.float32)
            self.state_in.extend([rm, rv])
            self.state_out.extend([rm, rv])
            return rm, rv
        rm = self.state_in[self._s]
        rv = self.state_in[self._s + 1]
        self._s += 2
        return rm, rv

    def push_bn_state(self, rm, rv):
        if not self.initializing:
            self.state_out.extend([rm, rv])


# ---------------------------------------------------------------------------
# initializers


def he_normal(rng, shape):
    fan_in = math.prod(shape[:-1])
    return jax.random.normal(rng, shape) * math.sqrt(2.0 / max(fan_in, 1))


def zeros(_rng, shape):
    return jnp.zeros(shape)


def ones(_rng, shape):
    return jnp.ones(shape)


def dense_init(rng, shape):
    fan_in = shape[0]
    bound = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.uniform(rng, shape, minval=-bound, maxval=bound)


# ---------------------------------------------------------------------------
# precision-aware layers (each consumes one runtime precision code)

_DN = lax.conv_dimension_numbers((1, 1, 1, 1), (1, 1, 1, 1), ("NHWC", "HWIO", "NHWC"))


def conv2d(
    store: Store,
    name: str,
    x: jnp.ndarray,
    features: int,
    kernel: int = 3,
    stride: int = 1,
    groups: int = 1,
    padding: str = "SAME",
):
    """Precision-adaptive conv: weights and input rounded to this layer's code."""
    cin = x.shape[-1]
    w = store.param(
        name + "/w",
        (kernel, kernel, cin // groups, features),
        he_normal,
        layer_idx=store._layer,  # the code this conv will consume
    )
    code = store.next_code()
    if not store.initializing:
        xq = api.qdq(x, code)
        wq = api.qdq(w, code)
    else:
        xq, wq = x, w
    dn = lax.conv_dimension_numbers(x.shape, w.shape, ("NHWC", "HWIO", "NHWC"))
    out = lax.conv_general_dilated(
        xq,
        wq,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=dn,
        feature_group_count=groups,
    )
    if store.initializing:
        hw = out.shape[1] * out.shape[2]
        macs = hw * kernel * kernel * (cin // groups) * features
        store.add_layer_spec(
            LayerSpec(
                name=name,
                kind="dwconv" if groups > 1 else "conv",
                param_elems=int(math.prod(w.shape)),
                act_elems=int(hw * features),
                flops=int(macs),
            )
        )
    return out


def dense(store: Store, name: str, x: jnp.ndarray, features: int, bias: bool = True):
    """Precision-adaptive dense head via the tiled mp_matmul Pallas kernel."""
    cin = x.shape[-1]
    w = store.param(name + "/w", (cin, features), dense_init, layer_idx=store._layer)
    b = store.param(name + "/b", (features,), zeros) if bias else None
    code = store.next_code()
    if store.initializing:
        out = jnp.matmul(x, w)
    else:
        out = api.mp_matmul(x, w, code)
    if b is not None:
        out = out + b
    if store.initializing:
        store.add_layer_spec(
            LayerSpec(
                name=name,
                kind="dense",
                param_elems=int(cin * features),
                act_elems=int(features),
                flops=int(cin * features),
            )
        )
    return out


def batchnorm(store: Store, name: str, x: jnp.ndarray):
    """BatchNorm2d with running stats (state threaded through the Store).

    Always fp32: AMP and the paper both keep normalization in full precision.
    """
    c = x.shape[-1]
    gamma = store.param(name + "/gamma", (c,), ones)
    beta = store.param(name + "/beta", (c,), zeros)
    rm, rv = store.bn_state((c,))
    if store.train or store.initializing:
        axes = tuple(range(x.ndim - 1))
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_rm = (1 - BN_MOMENTUM) * rm + BN_MOMENTUM * lax.stop_gradient(mean)
        new_rv = (1 - BN_MOMENTUM) * rv + BN_MOMENTUM * lax.stop_gradient(var)
        store.push_bn_state(new_rm, new_rv)
    else:
        mean, var = rm, rv
        store.push_bn_state(rm, rv)
    inv = lax.rsqrt(var + BN_EPS)
    return (x - mean) * inv * gamma + beta


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(x, axis=(1, 2))


def max_pool(x: jnp.ndarray, window: int = 2, stride: int = 2) -> jnp.ndarray:
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "VALID",
    )


# ---------------------------------------------------------------------------
# loss / metrics


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - ll)


def correct_count(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.sum((pred == labels.astype(jnp.int32)).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Model wrapper


@dataclasses.dataclass
class Model:
    """A built model: flat params/state plus the static specs Rust needs."""

    name: str
    num_classes: int
    forward: Callable  # forward(store, x) -> logits
    params: list
    state: list
    param_specs: list[ParamSpec]
    layer_specs: list[LayerSpec]

    @property
    def num_layers(self) -> int:
        return len(self.layer_specs)

    @property
    def param_count(self) -> int:
        return sum(math.prod(s.shape) for s in self.param_specs)

    def apply(self, params, state, x, codes, train: bool):
        """Returns (logits, new_state)."""
        store = Store(params=params, state=state, train=train)
        store.codes = codes
        logits = self.forward(store, x)
        assert store._layer == self.num_layers, (store._layer, self.num_layers)
        return logits, store.state_out


def build_model(name: str, num_classes: int, forward: Callable, sample_hw=(32, 32), seed=0) -> Model:
    """Trace `forward` once in init mode to materialize params + specs."""
    store = Store(rng=jax.random.PRNGKey(seed), train=True)
    x = jnp.zeros((1, sample_hw[0], sample_hw[1], 3), jnp.float32)
    forward(store, x)
    return Model(
        name=name,
        num_classes=num_classes,
        forward=forward,
        params=store.params,
        state=store.state_in,
        param_specs=store.param_specs,
        layer_specs=store.layer_specs,
    )
