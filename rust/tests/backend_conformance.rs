//! Backend conformance suite — the contract every [`Backend`]
//! implementation must satisfy (run here against the native reference
//! backend; a PJRT build can point the same suite at its engine).
//!
//! Covers: determinism across same-seed runs, train/eval/curv IO
//! arities matching the manifest contract, overflow-flag behaviour
//! under an absurd loss scale, and probe persistence semantics — run
//! over the whole graph-executor model grid (tiny_cnn, resnet_mini,
//! effnet_lite), not just the CI-speed model.

use tri_accel::manifest::{FP16, FP32};
use tri_accel::runtime::backend::Backend;
use tri_accel::runtime::native::{builtin_manifest, NativeBackend};
use tri_accel::runtime::{Batch, Engine, Session, StepCtrl};
use tri_accel::util::rng::Rng;

const MODEL: &str = "tiny_cnn_c10";
/// The full native model grid the conformance contract covers.
const GRID: [&str; 3] = ["tiny_cnn_c10", "resnet_mini_c10", "effnet_lite_c10"];

fn engine() -> Engine {
    Engine::native()
}

fn rand_batch(n: usize, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..n * 32 * 32 * 3).map(|_| rng.next_normal()).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    Batch::new(x, y)
}

#[test]
fn init_matches_manifest_shapes() {
    let m = builtin_manifest();
    let b = NativeBackend::new();
    for model in GRID {
        let entry = m.model(model).unwrap();
        let st = b.init(entry, 0).unwrap();
        assert_eq!(st.params.len(), entry.params.len(), "{model}");
        assert_eq!(st.mom.len(), entry.params.len(), "{model}");
        assert_eq!(st.state.len(), entry.state_shapes.len(), "{model}");
        for (p, spec) in st.params.iter().zip(&entry.params) {
            assert_eq!(p.len(), spec.elems, "{model}: {}", spec.name);
        }
        for (m_, spec) in st.mom.iter().zip(&entry.params) {
            assert_eq!(m_.len(), spec.elems, "{model}");
            assert!(m_.iter().all(|&v| v == 0.0), "{model}: momentum starts at zero");
        }
        for (s, shape) in st.state.iter().zip(&entry.state_shapes) {
            assert_eq!(s.len(), shape.iter().product::<usize>(), "{model}");
        }
        let total: usize = st.params.iter().map(|p| p.len()).sum();
        assert_eq!(total, entry.param_count, "{model}: param_count contract");
    }
}

#[test]
fn same_seed_runs_are_bit_identical_end_to_end() {
    let e = engine();
    for model in GRID {
        let run = || {
            let mut s = Session::init(&e, model, 42).unwrap();
            let n = s.num_layers();
            let ctrl = StepCtrl::uniform(n, FP32, 0.05, 5e-4);
            let mut trace = Vec::new();
            for i in 0..4 {
                let b = rand_batch(16, 10 + i);
                let out = s.train_step(&b, &ctrl).unwrap();
                trace.push((out.loss, out.correct, out.grad_var, out.grad_norm));
            }
            let eval = s
                .eval_batch(&rand_batch(16, 99), &vec![FP32; s.num_layers()])
                .unwrap();
            let lam = s
                .curv_step(&rand_batch(s.entry.curv_batch, 7), &vec![FP32; s.num_layers()], 13)
                .unwrap();
            (trace, eval.loss, eval.correct, lam, s.params_host().unwrap())
        };
        let a = run();
        let b = run();
        assert_eq!(a.0, b.0, "{model}: train trace");
        assert_eq!(a.1, b.1, "{model}: eval loss");
        assert_eq!(a.2, b.2, "{model}: eval correct");
        assert_eq!(a.3, b.3, "{model}: lambdas");
        assert_eq!(a.4, b.4, "{model}: final params");
    }
}

#[test]
fn io_arities_match_manifest_contract() {
    let e = engine();
    for model in GRID {
        let entry = e.manifest.model(model).unwrap().clone();
        let mut s = Session::init(&e, model, 0).unwrap();
        let l = entry.num_layers;

        // train: grad_var/grad_norm are per precision layer.
        let out = s
            .train_step(&rand_batch(16, 1), &StepCtrl::uniform(l, FP32, 0.05, 0.0))
            .unwrap();
        assert_eq!(out.grad_var.len(), l, "{model}");
        assert_eq!(out.grad_norm.len(), l, "{model}");

        // eval: total mirrors the batch; works for every advertised bucket.
        for &bucket in &entry.eval_buckets {
            let r = s.eval_batch(&rand_batch(bucket, 2), &vec![FP32; l]).unwrap();
            assert_eq!(r.total, bucket, "{model}");
        }

        // curv: lambdas are per precision layer, only at curv_batch.
        let lam = s
            .curv_step(&rand_batch(entry.curv_batch, 3), &vec![FP32; l], 5)
            .unwrap();
        assert_eq!(lam.len(), l, "{model}");
        assert!(
            s.curv_step(&rand_batch(16, 3), &vec![FP32; l], 5).is_err(),
            "{model}: wrong curvature batch size must be rejected"
        );

        // arity violations are loud.
        assert!(s
            .train_step(&rand_batch(16, 1), &StepCtrl::uniform(l + 1, FP32, 0.05, 0.0))
            .is_err());
        assert!(s.eval_batch(&rand_batch(16, 1), &vec![FP32; l + 1]).is_err());
    }
}

#[test]
fn every_train_bucket_executes() {
    let e = engine();
    for model in GRID {
        let entry = e.manifest.model(model).unwrap().clone();
        let mut s = Session::init(&e, model, 0).unwrap();
        let ctrl = StepCtrl::uniform(entry.num_layers, FP32, 0.01, 0.0);
        for &bucket in &entry.train_buckets {
            let out = s.train_step(&rand_batch(bucket, bucket as u64), &ctrl).unwrap();
            assert!(out.loss.is_finite(), "{model}: bucket {bucket}");
        }
    }
}

#[test]
fn overflow_fires_and_masks_under_absurd_loss_scale() {
    let e = engine();
    for model in GRID {
        let mut s = Session::init(&e, model, 6).unwrap();
        let n = s.num_layers();
        let before = s.params_host().unwrap();
        let b = rand_batch(16, 4);
        // FP16 layers + a loss scale far beyond binary16 range: the
        // scaled cotangents quantize to ±inf, the unscaled grads are
        // non-finite, and the whole update must be skipped.
        let mut ctrl = StepCtrl::uniform(n, FP16, 0.05, 0.0);
        ctrl.loss_scale = 1e30;
        let out = s.train_step(&b, &ctrl).unwrap();
        assert!(out.overflow, "{model}: overflow flag must fire");
        assert_eq!(s.params_host().unwrap(), before, "{model}: update must be masked");
        // grad stats of a poisoned step are non-finite, never fake zeros.
        assert!(out.grad_var.iter().any(|v| !v.is_finite()), "{model}");

        // The same batch at a sane scale trains normally.
        ctrl.loss_scale = 1024.0;
        let ok = s.train_step(&b, &ctrl).unwrap();
        assert!(!ok.overflow, "{model}");
        assert_ne!(s.params_host().unwrap(), before, "{model}");
    }
}

#[test]
fn probes_persist_and_reset_deterministically() {
    let e = engine();
    // tiny_cnn plus the depthwise architecture (the curvature path's
    // most distinct backward); resnet is covered by the arity test.
    for model in [MODEL, "effnet_lite_c10"] {
        let mut s = Session::init(&e, model, 0).unwrap();
        let codes = vec![FP32; s.num_layers()];
        let b = rand_batch(s.entry.curv_batch, 8);
        let l0 = s.curv_step(&b, &codes, 21).unwrap();
        let l1 = s.curv_step(&b, &codes, 21).unwrap();
        // The probe moved toward the dominant eigenvector, so successive
        // Rayleigh quotients differ (power iteration is progressing).
        assert_ne!(l0, l1, "{model}: probes must persist across firings");
        s.reset_probes();
        let l0_again = s.curv_step(&b, &codes, 21).unwrap();
        assert_eq!(l0, l0_again, "{model}: reset restarts the same seeded iteration");
    }
}

#[test]
fn eval_does_not_mutate_state() {
    let e = engine();
    for model in GRID {
        let mut s = Session::init(&e, model, 9).unwrap();
        let n = s.num_layers();
        // Train once so BN running stats are non-trivial.
        s.train_step(&rand_batch(16, 1), &StepCtrl::uniform(n, FP32, 0.05, 0.0))
            .unwrap();
        let params = s.params_host().unwrap();
        let r1 = s.eval_batch(&rand_batch(16, 2), &vec![FP32; n]).unwrap();
        let r2 = s.eval_batch(&rand_batch(16, 2), &vec![FP32; n]).unwrap();
        assert_eq!(r1.loss, r2.loss, "{model}: eval must be a pure function");
        assert_eq!(s.params_host().unwrap(), params, "{model}");
    }
}
