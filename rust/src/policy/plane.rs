//! §3.4 Unified Control Loop — the closed loop that couples the three
//! policies on a `T_ctrl` cadence:
//!
//! 1. collect per-layer gradient variance (every step, cheap EMA) and
//!    curvature (every `T_curv`, via the AOT curv graph);
//! 2. adjust precision allocations p_l(t);
//! 3. adapt per-layer learning rates from curvature;
//! 4. update batch size B(t) from the VRAM signal.
//!
//! The interdependencies the paper calls out are all mediated here:
//! curvature promotes precision ([`CurvaturePolicy::promotions`] →
//! [`PrecisionPolicy::promote`], gated on the precision policy being
//! adaptive), precision changes the memory model's input (codes),
//! memory drives batch size, and batch size feeds back into
//! gradient-variance statistics through the next steps' training.
//!
//! Unlike the pre-policy controller — which hardwired the three §3
//! state machines and gated them with method/ablation booleans — the
//! plane composes *any* policy triple. The method registry
//! ([`super::registry`]) names the useful compositions; the paper's
//! baselines fall out as `{pinned precision, no curvature, fixed
//! batch}`. The trainer talks to the plane only through the
//! observation/decision surface: [`ControlPlane::plan_step`] →
//! [`ControlPlane::observe_step`] / [`ControlPlane::observe_curvature`]
//! / [`ControlPlane::oom_event`] → [`ControlPlane::control_window`].

use crate::config::{Ablation, Config, Method};
use crate::manifest::{ModelEntry, BF16, FP16, FP32};

use super::batch::{BatchConfig, BatchController, BatchMove, FixedBatch};
use super::curvature::{CurvatureConfig, CurvatureScheduler, NoCurvature};
use super::precision::{LossScaler, PinnedPrecision, PrecisionConfig, PrecisionController};
use super::replica::{ReplicaConfig, ReplicaController, ReplicaMove};
use super::{ckpt_lookup_opt, BatchPolicy, CurvaturePolicy, PrecisionPolicy};

/// What one control window decided (telemetry / tests / traces).
#[derive(Debug, Clone)]
pub struct ControlDecision {
    pub step: u64,
    pub precision_changed: bool,
    pub promotions: Vec<usize>,
    pub batch_move: BatchMove,
    pub batch_size: usize,
    pub replica_move: ReplicaMove,
    pub replicas: usize,
    pub loss_scale: f32,
}

/// Everything the trainer needs to issue one optimizer step — the
/// decision half of the plane's observation/decision interface.
#[derive(Debug, Clone)]
pub struct StepPlan {
    pub batch_size: usize,
    pub codes: Vec<i32>,
    pub lr_scales: Vec<f32>,
    pub loss_scale: f32,
    /// Live data-parallel replica count (1 unless `--replicas` and a
    /// replicated backend are in play; never affects numerics).
    pub replicas: usize,
    /// Should the trainer run a curvature probe at this step?
    pub curvature_due: bool,
}

/// Per-policy decision counters (the "negligible overhead" telemetry
/// recorded into `BENCH_native.json`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PolicyCounts {
    pub windows: u64,
    pub precision_transitions: u64,
    pub batch_decisions: u64,
    pub replica_decisions: u64,
    pub curv_firings: u64,
    pub scaler_overflows: u64,
}

/// The §3.4 plane: a policy triple + the shared loss scaler on the
/// `T_ctrl` cadence.
pub struct ControlPlane {
    /// Table-1 family (metrics rows file under this).
    pub method: Method,
    /// Normalized ablation toggles (telemetry; non-TriAccel families
    /// report all-off, matching the composition actually built).
    pub ablation: Ablation,
    pub precision: Box<dyn PrecisionPolicy>,
    pub curvature: Box<dyn CurvaturePolicy>,
    pub batch: Box<dyn BatchPolicy>,
    /// The replica axis: elastic for `elastic_replicas` methods, a
    /// fixed (inert) count for everything else. Always present so the
    /// trainer has one surface regardless of method.
    pub replica: ReplicaController,
    pub scaler: LossScaler,
    t_ctrl: u64,
    windows: u64,
}

impl ControlPlane {
    /// Compose the policy triple a config describes. The paper's three
    /// methods resolve to exactly the pre-policy controller's behavior
    /// (bit-identical trajectories); registry methods additionally
    /// honor `pin_override` on the pinned-precision paths.
    pub fn new(cfg: &Config, entry: &ModelEntry) -> ControlPlane {
        let ablation = match cfg.method {
            Method::TriAccel => cfg.ablation,
            _ => Ablation::none(),
        };
        let adaptive = cfg.method == Method::TriAccel && ablation.dynamic_precision;
        let precision: Box<dyn PrecisionPolicy> = if adaptive {
            Box::new(PrecisionController::new(
                entry.num_layers,
                PrecisionConfig::from_cfg(cfg),
            ))
        } else {
            let code = cfg.pin_override.unwrap_or(match cfg.method {
                Method::Fp32 => FP32,
                _ => BF16,
            });
            Box::new(PinnedPrecision::new(entry.num_layers, code))
        };
        let curvature: Box<dyn CurvaturePolicy> =
            if cfg.method == Method::TriAccel && ablation.curvature {
                Box::new(CurvatureScheduler::new(
                    entry.num_layers,
                    CurvatureConfig::from_cfg(cfg),
                ))
            } else {
                Box::new(NoCurvature)
            };
        let batch: Box<dyn BatchPolicy> =
            if cfg.method == Method::TriAccel && ablation.dynamic_batch {
                Box::new(BatchController::new(
                    entry.train_buckets.clone(),
                    cfg.batch_init,
                    BatchConfig::from_cfg(cfg),
                ))
            } else {
                Box::new(FixedBatch::new(entry.train_buckets.clone(), cfg.batch_init))
            };
        // The scaler exists wherever sub-FP32 compute can: only the
        // pure-FP32 baseline runs without one.
        let all_fp32 = cfg.method == Method::Fp32 && cfg.pin_override.unwrap_or(FP32) == FP32;
        let scaler = if all_fp32 {
            LossScaler::disabled()
        } else {
            LossScaler::new(cfg.init_loss_scale, cfg.loss_scale_growth_interval)
        };
        // The replica axis: the count itself is workload shape
        // (`--replicas`); the *elasticity* is method
        // (`elastic_replicas` registry methods).
        let replica = ReplicaController::new(
            cfg.replicas,
            cfg.elastic_replicas,
            ReplicaConfig::from_cfg(cfg),
        );
        ControlPlane {
            method: cfg.method,
            ablation,
            precision,
            curvature,
            batch,
            replica,
            scaler,
            t_ctrl: cfg.t_ctrl.max(1),
            windows: 0,
        }
    }

    /// The decision bundle for one optimizer step at `step`.
    pub fn plan_step(&self, step: u64) -> StepPlan {
        StepPlan {
            batch_size: self.batch.current(),
            codes: self.codes(),
            lr_scales: self.lr_scales(),
            loss_scale: self.loss_scale(),
            replicas: self.replica.current(),
            curvature_due: self.curvature_due(step),
        }
    }

    /// Is the memory-elastic batch path active (vs the paper's static
    /// baselines, which keep B fixed and simply OOM)?
    pub fn batch_active(&self) -> bool {
        self.batch.elastic()
    }

    /// Is the curvature probe path active? (Gates the probe's memory
    /// accounting in the fit predictor.)
    pub fn curvature_active(&self) -> bool {
        self.curvature.active()
    }

    /// Per-step ingest: gradient variance + overflow flag from the train
    /// graph. O(L); runs every step.
    pub fn observe_step(&mut self, grad_var: &[f32], overflow: bool) {
        self.precision.observe(grad_var);
        // The scaler only matters while FP16 layers exist: BF16 shares
        // FP32's exponent range, so its overflow-free steps must not
        // grow the scale — a BF16-only run would otherwise ratchet the
        // scale to the cap while `loss_scale()` feeds 1.0 to the graph,
        // and a later FP16 demotion would inherit that absurd scale and
        // churn overflows until it halves back down. (The scaler itself
        // additionally clamps to [1, 65536].)
        if self.has_fp16_layers() {
            self.scaler.update(overflow);
        }
    }

    fn has_fp16_layers(&self) -> bool {
        self.precision.codes().contains(&FP16)
    }

    /// Should the trainer run a curvature probe at this step?
    pub fn curvature_due(&self, step: u64) -> bool {
        self.curvature.due(step)
    }

    /// Ingest probe results; returns layers whose probe vectors must be
    /// reset (non-finite λ).
    pub fn observe_curvature(&mut self, lambdas: &[f32]) -> Vec<usize> {
        self.curvature.observe(lambdas)
    }

    /// An actual (simulated or real) OOM happened at `step`. The
    /// elastic levers react immediately, cheapest first: a replica
    /// shed frees aggregate memory without touching the trajectory, so
    /// it goes before a batch shrink (which changes B); static
    /// baselines hold (and a real run would have crashed). True if
    /// either lever moved.
    pub fn oom_event(&mut self, step: u64) -> bool {
        if self.replica.force_shed(step) {
            return true;
        }
        self.batch.force_shrink(step)
    }

    /// Is `step` a control-window boundary (§3.4 cadence)?
    pub fn window_due(&self, step: u64) -> bool {
        step > 0 && step % self.t_ctrl == 0
    }

    /// One §3.4 control window. `mem_used`/`mem_max` from the memory
    /// monitor; `fits(b)` is the predictive OOM check for a candidate
    /// batch size *under the current precision codes*. Replica
    /// restores are never vetoed through this entry point — the
    /// trainer uses [`Self::control_window_replicated`], which takes
    /// the aggregate-VRAM fit predicate; with a fixed replica policy
    /// (every non-replica method) the two are identical.
    pub fn control_window<F: FnMut(usize) -> bool>(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        fits: F,
    ) -> ControlDecision {
        self.control_window_replicated(step, mem_used, mem_max, fits, |_| true)
    }

    /// One §3.4 control window with the replica axis live:
    /// `fits_replicas(n)` is the predictive check that the *current*
    /// batch fits the budget when `n` replicas are live (aggregate
    /// accounting across replicas, from `VramSim`).
    ///
    /// Lever ordering: replicas move first — shedding one frees every
    /// live replica's params/grads/workspace without touching the
    /// trajectory, so it is strictly cheaper than a batch shrink. The
    /// batch controller only acts in windows where the replica axis
    /// held (one memory lever per window keeps the response damped);
    /// with a fixed replica policy it acts every window, exactly as
    /// before the replica axis existed.
    pub fn control_window_replicated<F, G>(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        mut fits: F,
        mut fits_replicas: G,
    ) -> ControlDecision
    where
        F: FnMut(usize) -> bool,
        G: FnMut(usize) -> bool,
    {
        self.windows += 1;

        // (2) precision from variance; (3) promotion from curvature.
        // Promotions only flow when the precision policy is adaptive —
        // a pinned policy's codes are part of the method definition.
        let mut promotions = Vec::new();
        let mut precision_changed = false;
        if self.precision.adaptive() {
            precision_changed = self.precision.control_window();
            promotions = self.curvature.promotions();
            for &l in &promotions {
                self.precision.promote(l);
                precision_changed = true;
            }
        }

        // (4a) replicas from memory — the numerics-free lever.
        let replica_move = self.replica.update(step, mem_used, mem_max, &mut fits_replicas);

        // (4b) batch from memory, in windows where replicas held.
        let batch_move = match replica_move {
            ReplicaMove::Shed | ReplicaMove::Restore => BatchMove::Hold,
            ReplicaMove::Hold | ReplicaMove::VetoedRestore => {
                self.batch.update(step, mem_used, mem_max, &mut fits)
            }
        };

        ControlDecision {
            step,
            precision_changed,
            promotions,
            batch_move,
            batch_size: self.batch.current(),
            replica_move,
            replicas: self.replica.current(),
            loss_scale: self.scaler.scale(),
        }
    }

    /// The per-layer precision codes fed to the train executable.
    pub fn codes(&self) -> Vec<i32> {
        self.precision.codes().to_vec()
    }

    /// Per-layer LR scales; all-ones unless curvature is active+warm.
    pub fn lr_scales(&self) -> Vec<f32> {
        self.curvature.lr_scales(self.precision.num_layers())
    }

    /// The loss scale fed to the train executable. FP16 layers need a
    /// real scale; BF16/FP32-only runs use whatever the scaler holds
    /// (the graph divides it back out, so it is value-neutral).
    pub fn loss_scale(&self) -> f32 {
        if self.has_fp16_layers() {
            self.scaler.scale()
        } else {
            1.0
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch.current()
    }

    /// Live data-parallel replica count (1 for non-replicated runs).
    pub fn replicas(&self) -> usize {
        self.replica.current()
    }

    /// Is the elastic replica path active (an `elastic_replicas`
    /// method)?
    pub fn replica_active(&self) -> bool {
        self.replica.elastic()
    }

    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Per-policy decision counters (controller-overhead telemetry).
    pub fn counts(&self) -> PolicyCounts {
        PolicyCounts {
            windows: self.windows,
            precision_transitions: self.precision.transitions(),
            batch_decisions: self.batch.decisions(),
            replica_decisions: self.replica.decisions(),
            curv_firings: self.curvature.firings(),
            scaler_overflows: self.scaler.overflows(),
        }
    }

    /// Serialize every policy's state for checkpointing, namespaced
    /// per policy (`policy/<name>/…`), so a resumed run continues
    /// exactly where the saved one stopped (precision codes + variance
    /// EMAs, curvature EMAs, loss-scaler value, batch-ladder position
    /// and cooldown anchor).
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        let mut out = vec![("policy/plane/windows".to_string(), vec![self.windows as f64])];
        out.extend(self.precision.export_state());
        out.extend(self.curvature.export_state());
        out.extend(self.batch.export_state());
        out.extend(self.replica.export_state());
        out.extend(self.scaler.export_state());
        out
    }

    /// Restore state written by [`Self::export_state`], or by the
    /// pre-policy controller (legacy un-namespaced keys). The composed
    /// policies stay authoritative over what is state vs definition: a
    /// pinned precision policy keeps its pin (it only validates
    /// geometry), a fixed batch policy ignores saved ladder positions —
    /// exactly as the pre-policy controller re-applied pins after
    /// import and skipped the batch import when the elastic path was
    /// off.
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        if let Some(v) = ckpt_lookup_opt(kv, &["policy/plane/windows", "controller/windows"])
        {
            anyhow::ensure!(v.len() == 1, "plane windows arity");
            self.windows = v[0] as u64;
        }
        self.precision.import_state(kv)?;
        self.curvature.import_state(kv)?;
        self.batch.import_state(kv)?;
        // Pre-replica checkpoints carry no replica key: the controller
        // keeps its fresh position (the fixed configured count).
        self.replica.import_state(kv)?;
        self.scaler.import_state(kv)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::LayerSpec;
    use std::collections::BTreeMap;

    fn entry(num_layers: usize) -> ModelEntry {
        ModelEntry {
            key: "toy_c10".into(),
            model: "toy".into(),
            num_classes: 10,
            num_layers,
            param_count: 0,
            layers: (0..num_layers)
                .map(|i| LayerSpec {
                    name: format!("l{i}"),
                    kind: "conv".into(),
                    param_elems: 1000,
                    act_elems: 100,
                    flops: 10_000,
                })
                .collect(),
            params: vec![],
            nodes: vec![],
            state_shapes: vec![],
            train_buckets: vec![16, 32, 64, 96, 128],
            eval_buckets: vec![128],
            curv_batch: 32,
            artifacts: BTreeMap::new(),
        }
    }

    fn cfg(method: Method) -> Config {
        let mut c = Config::default();
        c.method = method;
        c.t_ctrl = 10;
        c.t_curv = 20;
        c.auto_threshold = false;
        c.tau_low = 1e-6;
        c.tau_high = 1e-3;
        c.batch_cooldown = 0;
        c
    }

    #[test]
    fn fp32_baseline_is_static() {
        let mut ctl = ControlPlane::new(&cfg(Method::Fp32), &entry(3));
        assert_eq!(ctl.codes(), vec![FP32, FP32, FP32]);
        assert!(!ctl.curvature_due(200));
        ctl.observe_step(&[1e-9, 1e-9, 1e-9], false);
        let d = ctl.control_window(10, 0.1, 1.0, |_| true);
        assert!(!d.precision_changed);
        assert_eq!(d.batch_move, BatchMove::Hold);
        assert_eq!(ctl.loss_scale(), 1.0);
        assert_eq!(ctl.lr_scales(), vec![1.0; 3]);
    }

    #[test]
    fn amp_static_is_uniform_bf16_fixed_batch() {
        let mut ctl = ControlPlane::new(&cfg(Method::AmpStatic), &entry(2));
        assert_eq!(ctl.codes(), vec![BF16, BF16]);
        for s in 1..=50 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.1, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![BF16, BF16], "static policy never moves");
        assert_eq!(ctl.batch_size(), 96);
    }

    #[test]
    fn tri_accel_adapts_precision_per_layer() {
        let mut ctl = ControlPlane::new(&cfg(Method::TriAccel), &entry(2));
        for s in 1..=60 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16, FP32], "low-var down, high-var up");
    }

    #[test]
    fn tri_accel_grows_batch_when_memory_free() {
        let mut ctl = ControlPlane::new(&cfg(Method::TriAccel), &entry(1));
        assert_eq!(ctl.batch_size(), 96);
        let d = ctl.control_window(10, 0.2, 1.0, |_| true);
        assert_eq!(d.batch_move, BatchMove::Grow);
        assert_eq!(ctl.batch_size(), 128);
    }

    #[test]
    fn ablation_flags_gate_components() {
        let mut c = cfg(Method::TriAccel);
        c.ablation.dynamic_precision = false;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        for s in 1..=60 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.2, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![BF16, BF16], "precision off → pinned");
        assert_eq!(ctl.batch_size(), 128, "batch still elastic");

        let mut c2 = cfg(Method::TriAccel);
        c2.ablation.dynamic_batch = false;
        let mut ctl2 = ControlPlane::new(&c2, &entry(2));
        let d = ctl2.control_window(10, 0.1, 1.0, |_| true);
        assert_eq!(d.batch_move, BatchMove::Hold, "batch off → fixed");
    }

    #[test]
    fn curvature_promotion_flows_into_precision() {
        let mut c = cfg(Method::TriAccel);
        c.tau_curv = 5.0;
        c.curv_warmup = 1;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        // Drive both layers to FP16 first.
        for s in 1..=40 {
            ctl.observe_step(&[1e-9, 1e-9], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16, FP16]);
        assert!(ctl.curvature_due(40), "t_curv=20 divides 40");
        ctl.observe_curvature(&[0.1, 50.0]);
        let d = ctl.control_window(50, 0.8, 1.0, |_| true);
        assert_eq!(d.promotions, vec![1]);
        assert_eq!(ctl.codes()[1], FP32, "steep layer promoted");
        assert_eq!(ctl.codes()[0], FP16, "flat layer untouched");
    }

    #[test]
    fn promotions_do_not_reach_pinned_precision() {
        // Curvature on, dynamic precision off: the probe path runs (LR
        // scales move) but the pinned codes must not — the pre-policy
        // controller gated the promotion flow on the adaptive path.
        let mut c = cfg(Method::TriAccel);
        c.ablation.dynamic_precision = false;
        c.tau_curv = 5.0;
        c.curv_warmup = 1;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        ctl.observe_curvature(&[60.0, 60.0]);
        let d = ctl.control_window(10, 0.8, 1.0, |_| true);
        assert!(d.promotions.is_empty(), "pinned policy reports no promotions");
        assert_eq!(ctl.codes(), vec![BF16, BF16]);
        assert!(ctl.lr_scales().iter().all(|&s| s < 1.0), "curvature still scales LR");
    }

    #[test]
    fn loss_scale_only_applies_with_fp16_layers() {
        let ctl = ControlPlane::new(&cfg(Method::AmpStatic), &entry(1));
        // BF16-only: graph receives neutral scale.
        assert_eq!(ctl.loss_scale(), 1.0);
        let mut c = cfg(Method::TriAccel);
        c.init_loss_scale = 512.0;
        let mut ctl2 = ControlPlane::new(&c, &entry(1));
        for s in 1..=30 {
            ctl2.observe_step(&[1e-9], false);
            if ctl2.window_due(s) {
                ctl2.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl2.codes(), vec![FP16]);
        assert_eq!(ctl2.loss_scale(), 512.0);
        // Overflow halves it.
        ctl2.observe_step(&[1e-9], true);
        assert_eq!(ctl2.loss_scale(), 256.0);
    }

    #[test]
    fn bf16_only_run_never_moves_the_scale() {
        // The satellite bug: BF16 layers used to count as "half", so a
        // BF16-only run doubled the scale every growth interval while
        // feeding 1.0 to the graph — a later FP16 demotion then started
        // at an absurd scale. Scaler updates are now FP16-gated.
        let mut c = cfg(Method::AmpStatic);
        c.loss_scale_growth_interval = 2;
        c.init_loss_scale = 1024.0;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        for _ in 0..50 {
            ctl.observe_step(&[1e-9, 1e-9], false);
        }
        assert_eq!(ctl.scaler.scale(), 1024.0, "BF16-only must not grow the scale");
        assert_eq!(ctl.loss_scale(), 1.0);
    }

    #[test]
    fn fp16_layers_drive_the_scaler() {
        let mut c = cfg(Method::TriAccel);
        c.loss_scale_growth_interval = 3;
        c.init_loss_scale = 512.0;
        let mut ctl = ControlPlane::new(&c, &entry(1));
        // Drive the single layer to FP16.
        for s in 1..=30 {
            ctl.observe_step(&[1e-9], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16]);
        let s0 = ctl.scaler.scale();
        for _ in 0..3 {
            ctl.observe_step(&[1e-9], false);
        }
        assert_eq!(ctl.scaler.scale(), s0 * 2.0, "clean FP16 steps grow the scale");
        assert!(ctl.scaler.scale() <= 65536.0);
    }

    #[test]
    fn pinned_fp16_composition_drives_the_scaler_from_step_one() {
        // The amp_dynamic registry method: uniform FP16, loss-scale
        // driven. No adaptation phase — the scaler is live immediately.
        let mut c = cfg(Method::AmpStatic);
        c.pin_override = Some(FP16);
        c.init_loss_scale = 1024.0;
        c.loss_scale_growth_interval = 4;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        assert_eq!(ctl.codes(), vec![FP16, FP16]);
        assert_eq!(ctl.loss_scale(), 1024.0);
        ctl.observe_step(&[1e-9, 1e-9], true);
        assert_eq!(ctl.loss_scale(), 512.0, "overflow halves the live scale");
        for _ in 0..4 {
            ctl.observe_step(&[1e-9, 1e-9], false);
        }
        assert_eq!(ctl.loss_scale(), 1024.0, "clean streak doubles it back");
        assert_eq!(ctl.batch_size(), 96, "batch stays fixed");
    }

    #[test]
    fn plan_step_matches_the_piecewise_getters() {
        let mut ctl = ControlPlane::new(&cfg(Method::TriAccel), &entry(2));
        for s in 1..=20 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.2, 1.0, |_| true);
            }
        }
        let plan = ctl.plan_step(20);
        assert_eq!(plan.batch_size, ctl.batch_size());
        assert_eq!(plan.codes, ctl.codes());
        assert_eq!(plan.lr_scales, ctl.lr_scales());
        assert_eq!(plan.loss_scale, ctl.loss_scale());
        assert_eq!(plan.curvature_due, ctl.curvature_due(20));
        assert_eq!(ctl.plan_step(19).curvature_due, ctl.curvature_due(19));
    }

    #[test]
    fn controller_state_roundtrips() {
        let mut c = cfg(Method::TriAccel);
        c.tau_curv = 5.0;
        c.curv_warmup = 1;
        let mut ctl = ControlPlane::new(&c, &entry(3));
        for s in 1..=45 {
            ctl.observe_step(&[1e-9, 1e-4, 1.0], s % 13 == 0);
            if s % 20 == 0 {
                ctl.observe_curvature(&[0.5, 2.0, 10.0]);
            }
            if ctl.window_due(s) {
                ctl.control_window(s, 0.85, 1.0, |_| true);
            }
        }
        let saved = ctl.export_state();
        let mut fresh = ControlPlane::new(&c, &entry(3));
        fresh.import_state(&saved).unwrap();
        assert_eq!(fresh.codes(), ctl.codes());
        assert_eq!(fresh.batch_size(), ctl.batch_size());
        assert_eq!(fresh.scaler.scale(), ctl.scaler.scale());
        assert_eq!(fresh.lr_scales(), ctl.lr_scales());
        assert_eq!(fresh.windows(), ctl.windows());
        assert_eq!(fresh.precision.transitions(), ctl.precision.transitions());
        // Continued evolution must match step for step.
        for s in 46..=60 {
            ctl.observe_step(&[1e-9, 1e-4, 1.0], false);
            fresh.observe_step(&[1e-9, 1e-4, 1.0], false);
            if ctl.window_due(s) {
                let a = ctl.control_window(s, 0.5, 1.0, |_| true);
                let b = fresh.control_window(s, 0.5, 1.0, |_| true);
                assert_eq!(a.batch_size, b.batch_size);
                assert_eq!(a.loss_scale, b.loss_scale);
            }
            assert_eq!(ctl.codes(), fresh.codes());
        }
        // A mismatched geometry is rejected loudly.
        let mut wrong = ControlPlane::new(&c, &entry(2));
        assert!(wrong.import_state(&saved).is_err());
    }

    #[test]
    fn legacy_unnamespaced_state_imports() {
        // A pre-policy (v2 checkpoint) controller section: the same
        // vectors under the old keys must restore the same plane.
        let mut c = cfg(Method::TriAccel);
        c.curv_warmup = 1;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        for s in 1..=30 {
            ctl.observe_step(&[1e-9, 1e-2], false);
            if s % 10 == 0 {
                ctl.observe_curvature(&[1.0, 2.0]);
            }
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        let legacy: Vec<(String, Vec<f64>)> = ctl
            .export_state()
            .into_iter()
            .map(|(k, v)| {
                let k = k
                    .replace("policy/plane/windows", "controller/windows")
                    .replace("policy/precision.adaptive/", "precision/")
                    .replace("policy/curvature.amortized/", "curvature/")
                    .replace("policy/batch.elastic/state", "batch/state")
                    .replace("policy/scaler/state", "scaler/state");
                (k, v)
            })
            .collect();
        let mut fresh = ControlPlane::new(&c, &entry(2));
        fresh.import_state(&legacy).unwrap();
        assert_eq!(fresh.codes(), ctl.codes());
        assert_eq!(fresh.batch_size(), ctl.batch_size());
        assert_eq!(fresh.windows(), ctl.windows());
        assert_eq!(fresh.scaler.scale(), ctl.scaler.scale());
        assert_eq!(fresh.lr_scales(), ctl.lr_scales());
    }

    #[test]
    fn counts_track_policy_decisions() {
        let mut ctl = ControlPlane::new(&cfg(Method::TriAccel), &entry(2));
        for s in 1..=40 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.2, 1.0, |_| true);
            }
        }
        let c = ctl.counts();
        assert_eq!(c.windows, 4);
        assert!(c.precision_transitions > 0, "codes moved");
        assert!(c.batch_decisions > 0, "batch grew");
        // Static baseline: everything zero except windows.
        let mut base = ControlPlane::new(&cfg(Method::Fp32), &entry(2));
        base.control_window(10, 0.2, 1.0, |_| true);
        let b = base.counts();
        assert_eq!(b.windows, 1);
        assert_eq!(b.precision_transitions, 0);
        assert_eq!(b.batch_decisions, 0);
        assert_eq!(b.curv_firings, 0);
    }

    #[test]
    fn window_cadence() {
        let ctl = ControlPlane::new(&cfg(Method::TriAccel), &entry(1));
        assert!(!ctl.window_due(0));
        assert!(ctl.window_due(10));
        assert!(!ctl.window_due(15));
        assert!(ctl.window_due(20));
    }

    #[test]
    fn elastic_replicas_shed_before_batch_and_restore_with_headroom() {
        let mut c = cfg(Method::TriAccel);
        c.replicas = 4;
        c.elastic_replicas = true;
        let mut ctl = ControlPlane::new(&c, &entry(2));
        assert!(ctl.replica_active());
        assert_eq!(ctl.plan_step(0).replicas, 4, "elastic starts at full capacity");
        // Pressure: the replica axis absorbs it; the batch holds.
        let d = ctl.control_window_replicated(10, 0.95, 1.0, |_| true, |_| true);
        assert_eq!(d.replica_move, ReplicaMove::Shed);
        assert_eq!(d.replicas, 2);
        assert_eq!(d.batch_move, BatchMove::Hold, "one memory lever per window");
        assert_eq!(ctl.batch_size(), 96);
        // Continued pressure sheds to the floor, then the batch moves.
        ctl.control_window_replicated(20, 0.95, 1.0, |_| true, |_| true);
        assert_eq!(ctl.replicas(), 1);
        let d = ctl.control_window_replicated(30, 0.95, 1.0, |_| true, |_| true);
        assert_eq!(d.replica_move, ReplicaMove::Hold);
        assert_eq!(d.batch_move, BatchMove::Shrink, "replica floor → batch lever");
        // Headroom: restore honors the aggregate-VRAM veto.
        let d = ctl.control_window_replicated(40, 0.2, 1.0, |_| true, |_| false);
        assert_eq!(d.replica_move, ReplicaMove::VetoedRestore);
        assert_eq!(d.batch_move, BatchMove::Grow, "vetoed restore frees the window");
        let d = ctl.control_window_replicated(50, 0.2, 1.0, |_| true, |_| true);
        assert_eq!(d.replica_move, ReplicaMove::Restore);
        assert_eq!(d.replicas, 2);
        assert_eq!(d.batch_move, BatchMove::Hold);
        assert!(ctl.counts().replica_decisions >= 4);
    }

    #[test]
    fn oom_sheds_replicas_before_shrinking_the_batch() {
        let mut c = cfg(Method::TriAccel);
        c.replicas = 2;
        c.elastic_replicas = true;
        let mut ctl = ControlPlane::new(&c, &entry(1));
        assert!(ctl.oom_event(5));
        assert_eq!(ctl.replicas(), 1);
        assert_eq!(ctl.batch_size(), 96, "batch untouched while replicas can shed");
        assert!(ctl.oom_event(6));
        assert_eq!(ctl.replicas(), 1);
        assert_eq!(ctl.batch_size(), 64, "replica floor → batch shrink");
    }

    #[test]
    fn non_replica_methods_pin_the_replica_count() {
        let mut c = cfg(Method::TriAccel);
        c.replicas = 2; // workload shape without an elastic_replicas method
        let mut ctl = ControlPlane::new(&c, &entry(1));
        assert!(!ctl.replica_active());
        assert_eq!(ctl.plan_step(0).replicas, 2);
        let d = ctl.control_window(10, 0.99, 1.0, |_| true);
        assert_eq!(d.replica_move, ReplicaMove::Hold);
        assert_eq!(d.replicas, 2);
        assert_eq!(d.batch_move, BatchMove::Shrink, "batch lever acts as before");
        ctl.oom_event(11);
        assert_eq!(ctl.replicas(), 2, "fixed count never sheds");
        assert_eq!(ctl.counts().replica_decisions, 0);
    }

    #[test]
    fn replica_state_roundtrips_and_legacy_checkpoints_stay_fixed() {
        let mut c = cfg(Method::TriAccel);
        c.replicas = 4;
        c.elastic_replicas = true;
        let mut ctl = ControlPlane::new(&c, &entry(1));
        ctl.control_window_replicated(10, 0.95, 1.0, |_| true, |_| true);
        assert_eq!(ctl.replicas(), 2);
        let saved = ctl.export_state();
        let mut fresh = ControlPlane::new(&c, &entry(1));
        fresh.import_state(&saved).unwrap();
        assert_eq!(fresh.replicas(), 2);
        assert_eq!(
            fresh.counts().replica_decisions,
            ctl.counts().replica_decisions
        );
        // A pre-replica checkpoint (no replica key) restores cleanly
        // and keeps the fresh full-capacity position.
        let legacy: Vec<(String, Vec<f64>)> = saved
            .into_iter()
            .filter(|(k, _)| !k.contains("replica"))
            .collect();
        let mut old = ControlPlane::new(&c, &entry(1));
        old.import_state(&legacy).unwrap();
        assert_eq!(old.replicas(), 4);
    }
}
