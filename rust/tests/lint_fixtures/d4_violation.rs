fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}

fn fma_tile_x86(acc: __m256, x: __m256, y: __m256) -> __m256 {
    _mm256_fmadd_ps(x, y, acc)
}

fn fma_tile_neon(acc: float32x4_t, x: float32x4_t, y: float32x4_t) -> float32x4_t {
    vfmaq_f32(acc, x, y)
}
