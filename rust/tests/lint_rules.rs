//! Fixture corpus for the detlint rule engine — one positive and one
//! negative case per rule D1–D7 plus pragma hygiene — and the gate
//! that matters: the crate's own `src/` tree must be lint-clean.
//!
//! Fixtures live in `tests/lint_fixtures/` as plain `.rs` text (never
//! compiled); each is linted under a pseudo relative path because the
//! rules are path-scoped.

use std::path::Path;

use tri_accel::lint::{lint_source, schema, Finding};

fn rule_ids(findings: &[Finding]) -> Vec<&str> {
    findings.iter().map(|f| f.rule.as_str()).collect()
}

macro_rules! fixture {
    ($name:literal) => {
        include_str!(concat!("lint_fixtures/", $name))
    };
}

#[test]
fn d1_flags_hash_collections_in_deterministic_dirs() {
    let f = lint_source("sched/fixture.rs", fixture!("d1_violation.rs"));
    assert!(!f.is_empty(), "HashMap in sched/ must be flagged");
    assert!(rule_ids(&f).iter().all(|r| *r == "d1"), "{f:?}");
    assert!(lint_source("util/fixture.rs", fixture!("d1_violation.rs")).is_empty());
    assert!(lint_source("sched/fixture.rs", fixture!("d1_clean.rs")).is_empty());
}

#[test]
fn d2_flags_wall_clock_reads() {
    let f = lint_source("policy/fixture.rs", fixture!("d2_violation.rs"));
    assert_eq!(rule_ids(&f), ["d2"], "{f:?}");
    assert!(lint_source("policy/fixture.rs", fixture!("d2_clean.rs")).is_empty());
}

#[test]
fn d2_flags_host_environment_reads() {
    let f = lint_source("memsim/fixture.rs", fixture!("d2_proc_violation.rs"));
    assert_eq!(rule_ids(&f), ["d2"], "a /proc/ read without a pragma must be flagged: {f:?}");
    assert!(
        lint_source("memsim/fixture.rs", fixture!("d2_proc_clean.rs")).is_empty(),
        "a justified pragma (and a prose mention in a comment) must pass"
    );
}

#[test]
fn d3_flags_thread_creation_outside_the_pools() {
    let f = lint_source("metrics/fixture.rs", fixture!("d3_violation.rs"));
    assert_eq!(rule_ids(&f), ["d3"], "{f:?}");
    let in_pool = lint_source("runtime/native/pool.rs", fixture!("d3_violation.rs"));
    assert!(in_pool.is_empty(), "the pool module itself is allowed to spawn");
}

#[test]
fn d4_flags_unpinned_float_reductions() {
    // Three violations: an unpinned `.sum`, an AVX2 fmadd, a NEON fma.
    let f = lint_source("runtime/native/fixture.rs", fixture!("d4_violation.rs"));
    assert_eq!(rule_ids(&f), ["d4", "d4", "d4"], "{f:?}");
    let data = lint_source("data/fixture.rs", fixture!("d4_violation.rs"));
    assert_eq!(rule_ids(&data), ["d4", "d4", "d4"], "data/ is in scope too");
    assert!(lint_source("util/fixture.rs", fixture!("d4_violation.rs")).is_empty());
    assert!(lint_source("runtime/native/fixture.rs", fixture!("d4_clean.rs")).is_empty());
}

#[test]
fn d5_requires_safety_comments_on_unsafe() {
    let f = lint_source("util/fixture.rs", fixture!("d5_violation.rs"));
    assert_eq!(rule_ids(&f), ["d5"], "{f:?}");
    assert!(lint_source("util/fixture.rs", fixture!("d5_clean.rs")).is_empty());
}

#[test]
fn d6_flags_unwrap_in_library_code() {
    let f = lint_source("policy/fixture.rs", fixture!("d6_violation.rs"));
    assert_eq!(rule_ids(&f), ["d6"], "{f:?}");
    assert!(lint_source("policy/fixture.rs", fixture!("d6_clean.rs")).is_empty());
}

#[test]
fn d7_schema_pin_matches_the_extracted_field_set() {
    let (version, keys) = schema::extract(fixture!("d7_schema.rs"), "SCHEMA_VERSION");
    assert_eq!(version, Some(1));
    let names: Vec<&str> = keys.iter().map(String::as_str).collect();
    assert_eq!(names, ["alpha", "beta", "gamma"], "test-region keys must be ignored");
    let digest = schema::digest_keys(&keys);
    let pin = schema::SchemaPin {
        file: "metrics/fixture.rs",
        version_const: "SCHEMA_VERSION",
        version: 1,
        digest,
    };
    let (f, status) = schema::check_extracted(&pin, version, &keys);
    assert!(f.is_empty(), "{f:?}");
    assert_eq!(status.digest, status.pinned_digest);
}

#[test]
fn d7_drift_without_a_version_bump_is_a_finding() {
    let (version, keys) = schema::extract(fixture!("d7_schema.rs"), "SCHEMA_VERSION");
    let digest = schema::digest_keys(&keys);
    let stale = schema::SchemaPin {
        file: "metrics/fixture.rs",
        version_const: "SCHEMA_VERSION",
        version: 1,
        digest: digest ^ 1,
    };
    let (f, _) = schema::check_extracted(&stale, version, &keys);
    assert_eq!(rule_ids(&f), ["d7"], "{f:?}");
    assert!(f[0].message.contains("drifted"), "{}", f[0].message);

    let bumped = schema::SchemaPin {
        file: "metrics/fixture.rs",
        version_const: "SCHEMA_VERSION",
        version: 2,
        digest,
    };
    let (f, _) = schema::check_extracted(&bumped, version, &keys);
    assert_eq!(rule_ids(&f), ["d7"], "{f:?}");
    assert!(f[0].message.contains("lint pins"), "{}", f[0].message);
}

#[test]
fn malformed_pragmas_are_findings_and_do_not_suppress() {
    let f = lint_source("policy/fixture.rs", fixture!("pragma_violation.rs"));
    let ids = rule_ids(&f);
    assert_eq!(ids.iter().filter(|r| **r == "pragma").count(), 2, "{f:?}");
    assert_eq!(ids.iter().filter(|r| **r == "d6").count(), 1, "a broken pragma must not allow");
}

#[test]
fn crate_source_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = tri_accel::lint::lint_tree(&root).expect("lint the src tree");
    assert!(report.files_scanned > 40, "only scanned {} files", report.files_scanned);
    assert!(report.clean(), "detlint findings in src/:\n{}", report.human());
    assert_eq!(report.schemas.len(), 2, "telemetry + ledger pins");
}
