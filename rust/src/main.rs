//! `tri-accel` — leader entrypoint / CLI.
//!
//! Subcommands (full reference with examples: `docs/CLI.md`):
//!   info                          backend + model inventory
//!   train    [--model K] [--method M] [--epochs N] [--replicas N] [--set k=v ...]
//!   table1   [--models a,b] [--seeds 0,1,2] [--jobs N] [--replicas N] [--smoke]
//!   table2   [--model K]    [--seeds 0,1,2] [--jobs N] [--replicas N]
//!   fig      [--model K]    [--seed S]      [--jobs N] [--replicas N]
//!   pressure [--model K] [--methods a,b] [--trace SPEC | --scenario NAME] [--jobs N] [--smoke]
//!   chaos    [--grid table1|table2|fig|pressure] [--faults SPEC] [--retries N] + grid flags
//!   trace    --record (--events F | --grid DIR) --out F | --show F | --verify --a DIR --b DIR
//!   compare --a run.json --b run.json
//!   report   [--out runs] [--dir DIR]
//!   lint     [--format human|json] [--out FILE] [--root DIR]
//!   tune     [--shapes MxKxN,...] [--reps N] [--threads N]
//!
//! Global flags: `--list-models` (manifest inventory) and
//! `--list-methods` (the method registry) print and exit. `--method`
//! accepts any registry key (`--list-methods`), not just the paper's
//! three columns. `--no-autotune` ignores the GEMM tuning cache for
//! this run (every kernel uses the default blocking; see
//! docs/ARCHITECTURE.md "SIMD dispatch & autotuning"). `--mem-source
//! host` (train) samples the process's real RSS at control windows
//! into `host_mem` telemetry; deterministic artifacts still come from
//! the simulator (docs/MEMORY.md).
//!
//! The grid subcommands (`table1`/`table2`/`fig`/`pressure`) run on
//! the experiment scheduler: `--jobs N` executes cells concurrently,
//! `--threads` caps the *total* compute-thread budget shared by all
//! jobs, `--replicas N` (1|2|4) trains every job as N deterministic
//! data-parallel replicas (numerics-neutral — bit-identical losses and
//! decisions at any count; elastic shedding under the
//! `tri_accel_replica` method), and every grid persists a resumable
//! ledger plus JSONL
//! telemetry under `runs/<grid-id>/` — rerunning the same command
//! resumes a killed grid bit-identically. `report` re-renders the
//! markdown/JSON artifacts from the ledgers alone. Every grid runs
//! under the job supervisor (`--retries N` bounded retries with
//! virtual-clock backoff, quarantine on exhaustion) and accepts a
//! seeded `--faults SPEC` fault plan; `chaos` runs a grid under a
//! plan and verifies the artifacts stay bit-identical to the
//! fault-free run (`docs/FAULTS.md`).
//!
//! Backend selection (train/info): `--backend native` (default — the
//! hermetic pure-Rust executor) or `--backend pjrt` (`--features
//! pjrt` builds only; reads `--artifacts <dir>`). `--threads N` pins
//! the native compute core's worker count (output is bit-identical
//! for every value — see README "Performance").

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use tri_accel::config::Config;
use tri_accel::faults;
use tri_accel::harness;
use tri_accel::metrics::PrecisionMix;
use tri_accel::policy::registry;
use tri_accel::runtime::Engine;
use tri_accel::sched;
use tri_accel::train::Trainer;
use tri_accel::util::args::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    // Registry/inventory flags short-circuit any subcommand: print and
    // exit so scripts can discover what a build serves.
    if args.flag("list-methods") {
        return list_methods();
    }
    if args.flag("list-models") {
        let engine = engine_from(&args)?;
        return list_models(&engine);
    }
    if args.flag("no-autotune") {
        tri_accel::runtime::native::autotune::set_enabled(false);
    }
    match args.subcommand.as_deref() {
        Some("info") => info(&args),
        Some("train") | None => train(&args),
        Some("table1") => table1(&args),
        Some("table2") => table2(&args),
        Some("fig") => fig(&args),
        Some("pressure") => pressure(&args),
        Some("chaos") => chaos(&args),
        Some("trace") => trace_cmd(&args),
        Some("compare") => compare(&args),
        Some("report") => report(&args),
        Some("lint") => lint(&args),
        Some("tune") => tune(&args),
        Some(other) => {
            anyhow::bail!(
                "unknown subcommand `{other}` \
                 (info|train|table1|table2|fig|pressure|chaos|trace|compare|report|lint|tune)"
            )
        }
    }
}

/// `lint`: the detlint static-analysis pass over this crate's own
/// source tree (rule table and pragma grammar: `docs/DETERMINISM.md`).
/// Prints the report (`--format human|json`), always writes the JSON
/// report to `--out` when given (the CI artifact), and exits nonzero
/// on any finding.
fn lint(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.get_or("root", concat!(env!("CARGO_MANIFEST_DIR"), "/src")));
    let format = args.get_or("format", "human");
    let out = args.get("out").map(PathBuf::from);
    args.reject_unknown()?;
    anyhow::ensure!(
        format == "human" || format == "json",
        "--format must be `human` or `json`, got `{format}`"
    );
    let report = tri_accel::lint::lint_tree(&root)?;
    if let Some(ref p) = out {
        std::fs::write(p, report.json()).with_context(|| format!("writing {}", p.display()))?;
    }
    if format == "json" {
        println!("{}", report.json());
    } else {
        print!("{}", report.human());
    }
    anyhow::ensure!(
        report.clean(),
        "detlint: {} finding(s) — fix each one or exempt it with a justified pragma",
        report.findings.len()
    );
    Ok(())
}

/// `tune`: search the GEMM blocking candidates per dispatch tier for a
/// set of shapes and persist the winners to the on-disk tuning cache
/// (`TRIACCEL_TUNE_CACHE`, default `triaccel_tune.json` in the working
/// directory). Safe by construction: every candidate is bit-identical
/// within a tier, so tuning changes speed, never numbers
/// (docs/DETERMINISM.md).
fn tune(args: &Args) -> Result<()> {
    use tri_accel::runtime::native::{arena::Arena, autotune, pool::Pool, simd};
    let threads: usize = args.parse_or("threads", 0)?;
    let reps: usize = args.parse_or("reps", 3)?;
    anyhow::ensure!(reps >= 1, "--reps must be at least 1");
    let shapes = args.get_or("shapes", "8192x144x32,1024x64x64,16384x27x16,16x64x10");
    args.reject_unknown()?;
    anyhow::ensure!(
        autotune::enabled(),
        "autotuning is disabled (--no-autotune / TRIACCEL_NO_AUTOTUNE) — nothing to tune"
    );
    let pool = if threads > 0 { Pool::new(threads) } else { Pool::from_env() };
    let mut arena = Arena::new();
    for spec in shapes.split(',') {
        let dims: Vec<usize> = spec
            .trim()
            .split('x')
            .map(|d| d.parse::<usize>())
            .collect::<Result<_, _>>()
            .with_context(|| format!("--shapes entry `{spec}` (want MxKxN)"))?;
        anyhow::ensure!(dims.len() == 3, "--shapes entry `{spec}` must be MxKxN");
        let (m, k, n) = (dims[0], dims[1], dims[2]);
        for tier in simd::available_tiers() {
            let (cfg, err) = autotune::tune_and_save(&pool, &mut arena, tier, m, k, n, reps);
            if let Some(e) = err {
                return Err(anyhow::Error::new(e).context("writing the tuning cache"));
            }
            println!(
                "{m}x{k}x{n} [{tier}] threads {} -> row_chunk {} nr {}",
                pool.threads(),
                cfg.row_chunk,
                cfg.nr
            );
        }
    }
    println!("cache → {}", autotune::cache_path().display());
    Ok(())
}

/// `--list-methods`: the method registry — every named policy
/// composition `--method` accepts.
fn list_methods() -> Result<()> {
    println!(
        "{:<18} {:<20} {:<11} {:<28} description",
        "key", "label", "family", "policies (prec/batch/curv)"
    );
    for s in registry::registry() {
        let prec = if s.ablation.dynamic_precision { "adaptive" } else { "pinned" };
        let batch = if s.ablation.dynamic_batch { "elastic" } else { "fixed" };
        let curv = if s.ablation.curvature { "probed" } else { "off" };
        let policies = format!("{prec}/{batch}/{curv}");
        let key = if s.aliases.is_empty() {
            s.key.to_string()
        } else {
            format!("{} ({})", s.key, s.aliases.join("|"))
        };
        println!(
            "{:<18} {:<20} {:<11} {:<28} {}",
            key,
            s.label,
            s.family.name(),
            policies,
            s.about
        );
    }
    Ok(())
}

/// `--list-models`: the engine manifest's model inventory.
fn list_models(engine: &Engine) -> Result<()> {
    for key in engine.manifest.models.keys() {
        println!("{key}");
    }
    Ok(())
}

/// Build the engine from `--backend` / `--artifacts` / `--threads`
/// (`--threads 0` = auto: `TRIACCEL_THREADS`, else machine parallelism
/// capped at 8; native results are bit-identical for any count).
fn engine_from(args: &Args) -> Result<Engine> {
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let backend = args.get_or("backend", "native");
    let threads: usize = args.parse_or("threads", 0)?;
    if threads > 0 {
        anyhow::ensure!(
            backend == "native",
            "--threads pins the native compute core's workers; backend `{backend}` ignores it \
             (drop the flag or use --backend native)"
        );
        return Ok(Engine::native_with_threads(threads));
    }
    Engine::by_name(&backend, &artifacts)
}

/// `--replicas N`: deterministic data-parallel replica count (1, 2, or
/// 4). Numerics-neutral by construction — every loss, parameter, and
/// policy decision is bit-identical at any count (docs/DETERMINISM.md,
/// "ordered replica reduction") — so it is validated once here, before
/// any engine or grid is built.
fn parse_replicas(args: &Args) -> Result<usize> {
    let replicas: usize = args.parse_or("replicas", 1)?;
    anyhow::ensure!(
        matches!(replicas, 1 | 2 | 4),
        "--replicas must be 1, 2, or 4 (got {replicas})"
    );
    Ok(replicas)
}

/// Grid subcommands run on the scheduler's native job pool; reject an
/// explicit non-native backend instead of silently ignoring it.
fn require_native(args: &Args) -> Result<()> {
    let backend = args.get_or("backend", "native");
    let _ = args.get("artifacts"); // accepted (and unused) for script compatibility
    anyhow::ensure!(
        backend == "native",
        "grid subcommands (table1|table2|fig|pressure|chaos) run on the scheduler's \
         native job pool; `--backend {backend}` is only supported by train/info"
    );
    Ok(())
}

/// Scheduler knobs shared by the grid subcommands: `--jobs N`
/// concurrent cells, `--threads` total compute budget (split across
/// jobs so the machine is never oversubscribed), `--out` base
/// directory, `--retries N` supervisor retry budget per job,
/// `--faults SPEC` deterministic fault injection, `--quiet` to
/// suppress per-job lines. Invalid values are rejected here, at
/// parse time, before any job runs.
fn sched_opts(args: &Args) -> Result<sched::SchedOptions> {
    let jobs: usize = args.parse_or("jobs", 1)?;
    anyhow::ensure!(jobs >= 1, "--jobs must be at least 1");
    let retries: i64 = args.parse_or("retries", 2)?;
    anyhow::ensure!(
        (0..=1000).contains(&retries),
        "--retries must be between 0 and 1000, got {retries}"
    );
    let faults = match args.get("faults") {
        Some(spec) => {
            let f = faults::FaultSpec::parse(spec)?;
            if f.is_empty() {
                None
            } else {
                Some(f)
            }
        }
        None => None,
    };
    Ok(sched::SchedOptions {
        jobs,
        total_threads: args.parse_or("threads", 0)?,
        out_dir: PathBuf::from(args.get_or("out", "runs")),
        job_limit: None,
        quiet: args.flag("quiet"),
        retries: retries as usize,
        faults,
    })
}

/// The completed grid's ledger — the single aggregation source for
/// stdout tables (the same one the rendered artifacts used).
fn grid_ledger(outcome: &sched::GridOutcome) -> Result<&sched::Ledger> {
    outcome
        .ledger
        .as_ref()
        .context("grid did not complete (rerun the command to resume it)")
}

fn print_outcome(o: &sched::GridOutcome) {
    println!(
        "grid {} → {}  (jobs: {} executed, {} reused of {})",
        o.grid_id,
        o.grid_dir.display(),
        o.executed,
        o.reused,
        o.total
    );
    for a in &o.artifacts {
        println!("artifact → {}", a.display());
    }
}

/// Default model list: everything the engine's manifest serves.
fn all_models(engine: &Engine) -> String {
    engine
        .manifest
        .models
        .keys()
        .cloned()
        .collect::<Vec<_>>()
        .join(",")
}

/// `--model` defaulting to the CI-speed model when the manifest serves
/// it (the BTreeMap's first key would otherwise drift as the built-in
/// grid grows — e.g. to effnet_lite_c10), else the first entry.
fn model_or_first(args: &Args, engine: &Engine) -> Result<String> {
    if let Some(m) = args.get("model") {
        return Ok(m.to_string());
    }
    let default = Config::default().model_key;
    if engine.manifest.models.contains_key(&default) {
        return Ok(default);
    }
    Ok(engine
        .manifest
        .models
        .keys()
        .next()
        .context("empty manifest")?
        .clone())
}

/// Compare two run JSONs written by `train` (`runs/<tag>.json`): final
/// accuracy, time, peak VRAM, efficiency — the per-cell Table-1 delta.
fn compare(args: &Args) -> Result<()> {
    let a_path = args.get("a").context("--a <run.json> required")?.to_string();
    let b_path = args.get("b").context("--b <run.json> required")?.to_string();
    // Engine options are accepted (and ignored) everywhere for script
    // compatibility — compare needs no backend.
    let _ = args.get("artifacts");
    let _ = args.get("backend");
    let _ = args.get("threads");
    args.reject_unknown()?;
    let load = |p: &str| -> Result<(f64, f64, f64, f64)> {
        let j = tri_accel::util::json::Json::parse(&std::fs::read_to_string(p)?)
            .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
        let epochs = j.req("epochs")?.as_arr().context("epochs")?;
        let last = epochs.last().context("empty run")?;
        let acc = last.req("test_acc")?.as_f64().context("test_acc")?;
        let time = last.req("modeled_s_norm")?.as_f64().context("modeled_s_norm")?;
        let peak = epochs
            .iter()
            .filter_map(|e| e.get("peak_vram_gb").and_then(|v| v.as_f64()))
            .fold(0.0, f64::max);
        let eff = last.req("eff_score")?.as_f64().context("eff_score")?;
        Ok((acc, time, peak, eff))
    };
    let (aa, at, ap, ae) = load(&a_path)?;
    let (ba, bt, bp, be) = load(&b_path)?;
    println!("{:<28} {:>10} {:>10} {:>12}", "", "A", "B", "B vs A");
    let row = |name: &str, a: f64, b: f64, pct: bool| {
        let d = if pct { 100.0 * (b - a) / a.max(1e-12) } else { b - a };
        println!(
            "{:<28} {:>10.4} {:>10.4} {:>+11.2}{}",
            name,
            a,
            b,
            d,
            if pct { "%" } else { " " }
        );
    };
    row("test accuracy (%)", aa, ba, false);
    row("time/epoch (modeled s)", at, bt, true);
    row("peak VRAM (GB)", ap, bp, true);
    row("efficiency score", ae, be, true);
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    use tri_accel::runtime::native::{autotune, simd};
    let engine = engine_from(args)?;
    args.reject_unknown()?;
    println!("backend: {}", engine.platform());
    let tiers: Vec<&str> = simd::available_tiers().iter().map(|t| t.name()).collect();
    println!("dispatch: {} (available: {})", simd::active().name(), tiers.join(","));
    println!(
        "autotune: {} (cache: {})",
        if autotune::enabled() { "on" } else { "off" },
        autotune::cache_path().display()
    );
    println!(
        "{:<20} {:>7} {:>11} {:>8} {:>22}",
        "model", "layers", "params", "curv_b", "train buckets"
    );
    for (key, e) in &engine.manifest.models {
        println!(
            "{:<20} {:>7} {:>11} {:>8} {:>22?}",
            key, e.num_layers, e.param_count, e.curv_batch, e.train_buckets
        );
    }
    Ok(())
}

/// Build a Config from common CLI options + freeform --set k=v pairs.
fn config_from(args: &Args) -> Result<Config> {
    let mut cfg = Config::default();
    if let Some(path) = args.get("config") {
        cfg = Config::load(std::path::Path::new(path))?;
    }
    if let Some(m) = args.get("model") {
        cfg.model_key = m.to_string();
    }
    if let Some(m) = args.get("method") {
        // Registry-resolved: any named composition, and unknown names
        // print the full registry.
        cfg.set("method", m)?;
    }
    cfg.epochs = args.parse_or("epochs", cfg.epochs)?;
    cfg.seed = args.parse_or("seed", cfg.seed)?;
    if args.get("replicas").is_some() {
        cfg.replicas = parse_replicas(args)?;
    }
    if let Some(s) = args.get("steps") {
        cfg.steps_per_epoch = Some(s.parse().context("--steps")?);
    }
    if let Some(src) = args.get("mem-source") {
        cfg.set("mem_source", src)?;
    }
    // k=v escape hatch for every remaining hyperparameter.
    if let Some(sets) = args.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .with_context(|| format!("--set expects k=v, got `{kv}`"))?;
            cfg.set(k, v)?;
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

fn train(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    // A replicated config needs a replicated engine: split the thread
    // budget across the replicas so `replicas × threads` stays within
    // it, exactly like the scheduler's job-pool accounting.
    let engine = if cfg.replicas > 1 {
        use tri_accel::runtime::native::pool::{budget_threads, resolve_threads};
        let backend = args.get_or("backend", "native");
        anyhow::ensure!(
            backend == "native",
            "--replicas > 1 runs on the native replicated backend; \
             `--backend {backend}` is single-replica only"
        );
        let threads: usize = args.parse_or("threads", 0)?;
        let total = if threads > 0 {
            threads
        } else {
            resolve_threads(std::env::var("TRIACCEL_THREADS").ok().as_deref())
        };
        Engine::native_replicated(cfg.replicas, budget_threads(total, 1, cfg.replicas))
    } else {
        engine_from(args)?
    };
    harness::validate_models(&engine, &[cfg.model_key.as_str()])?;
    let out_dir = PathBuf::from(args.get_or("out", "runs"));
    let quiet = args.flag("quiet");
    let save_ckpt = args.get("save-ckpt").map(PathBuf::from);
    let resume = args.get("resume").map(PathBuf::from);
    args.reject_unknown()?;

    let method_key = registry::effective_key(&cfg);
    let tag = format!("{}_{}_s{}", cfg.model_key, method_key, cfg.seed);
    println!(
        "training {} with {} ({}) on {} — {} epochs, seed {}",
        cfg.model_key,
        cfg.method.name(),
        method_key,
        engine.platform(),
        cfg.epochs,
        cfg.seed
    );
    let epochs = cfg.epochs;
    let mut tr = Trainer::new(&engine, cfg)?;
    if let Some(ref p) = resume {
        let step = tr.resume_from(p)?;
        println!("resumed from {} at step {step}", p.display());
    }
    for epoch in 0..epochs {
        let r = tr.run_epoch(epoch)?;
        if let Some(ref p) = save_ckpt {
            tr.save_checkpoint(p)?;
        }
        if !quiet {
            let mix = r.mix;
            println!(
                "epoch {:>3}  loss {:.4}  train {:5.1}%  test {:5.1}%  wall {:6.2}s  modeled {:7.3}s  peak {:.4}GB  B̄ {:5.1}  mix {:.0}/{:.0}/{:.0}  score {:6.2}",
                r.epoch, r.train_loss, r.train_acc, r.test_acc, r.wall_s, r.modeled_s,
                r.peak_vram_gb, r.mean_batch,
                100.0 * mix.fp16, 100.0 * mix.bf16, 100.0 * mix.fp32,
                r.eff_score
            );
        }
    }
    let s = tr.summary();
    println!(
        "final: acc {:.2}%  time/epoch {:.2}s (wall {:.2}s)  peak {:.4}GB  score {:.2}",
        s.test_acc_pct, s.modeled_s_per_epoch, s.wall_s_per_epoch, s.peak_vram_gb, s.eff_score
    );
    tr.metrics.write(&out_dir, &tag)?;
    println!("metrics → {}/{}*.csv|json", out_dir.display(), tag);
    let _ = PrecisionMix::of(&tr.controller.codes());
    Ok(())
}

fn parse_seeds(args: &Args) -> Result<Vec<u64>> {
    args.get_or("seeds", "0,1,2")
        .split(',')
        .map(|s| s.parse::<u64>().context("--seeds"))
        .collect()
}

/// Build the Table-1 grid spec from the shared grid flags (also used
/// by `chaos --grid table1`). `--smoke` is the CI fast path — 1 seed,
/// a couple of steps, the full built-in architecture grid; explicit
/// `--steps`/`--epochs`/`--seeds` still win over the smoke defaults.
fn table1_grid(args: &Args, engine: &Engine) -> Result<sched::GridSpec> {
    let smoke = args.flag("smoke");
    let models = match args.get("models") {
        Some(m) => m.to_string(),
        None => all_models(engine),
    };
    let explicit_seeds = args.get("seeds").is_some();
    let mut seeds = parse_seeds(args)?;
    if smoke && !explicit_seeds {
        seeds.truncate(1);
    }
    let steps: usize = args.parse_or("steps", if smoke { 2 } else { 60 })?;
    let epochs: usize = args.parse_or("epochs", if smoke { 1 } else { 3 })?;
    let keys: Vec<&str> = models.split(',').collect();
    harness::validate_models(engine, &keys)?;
    let replicas = parse_replicas(args)?;
    let budget = harness::quick_budget(steps, epochs);
    let tweak = move |cfg: &mut Config| {
        budget(cfg);
        cfg.replicas = replicas;
    };
    Ok(sched::table1_spec(&keys, &seeds, &tweak))
}

fn table1(args: &Args) -> Result<()> {
    require_native(args)?;
    let engine = Engine::native();
    let smoke = args.flag("smoke");
    let spec = table1_grid(args, &engine)?;
    let opts = sched_opts(args)?;
    args.reject_unknown()?;
    let outcome = sched::run_grid(&spec, &opts)?;
    let rows = sched::report::cell_rows(grid_ledger(&outcome)?)?;
    println!(
        "== Table 1 ({}; shape comparison vs paper) ==",
        if smoke { "smoke budget" } else { "reduced budget" }
    );
    harness::print_table1(&rows);
    for chunk in rows.chunks(3) {
        println!("{} — {}", chunk[0].model_key, harness::headline(&chunk[0], &chunk[2]));
    }
    print_outcome(&outcome);
    Ok(())
}

/// Build the Table-2 ablation spec (also used by `chaos --grid table2`);
/// returns the spec plus the resolved model key for the header line.
fn table2_grid(args: &Args, engine: &Engine) -> Result<(sched::GridSpec, String)> {
    let model = model_or_first(args, engine)?;
    let seeds = parse_seeds(args)?;
    let steps: usize = args.parse_or("steps", 60)?;
    let epochs: usize = args.parse_or("epochs", 3)?;
    harness::validate_models(engine, &[model.as_str()])?;
    let replicas = parse_replicas(args)?;
    let budget = harness::quick_budget(steps, epochs);
    let tweak = move |cfg: &mut Config| {
        budget(cfg);
        cfg.replicas = replicas;
    };
    Ok((sched::table2_spec(&model, &seeds, &tweak), model))
}

fn table2(args: &Args) -> Result<()> {
    require_native(args)?;
    let engine = Engine::native();
    let (spec, model) = table2_grid(args, &engine)?;
    let opts = sched_opts(args)?;
    args.reject_unknown()?;
    let outcome = sched::run_grid(&spec, &opts)?;
    let rows = sched::report::cell_rows(grid_ledger(&outcome)?)?;
    println!("== Table 2 ablation — {model} ==");
    harness::print_table2(&rows);
    print_outcome(&outcome);
    Ok(())
}

/// Build the VRAM-pressure sweep spec (also used by
/// `chaos --grid pressure`); returns the spec plus the resolved model
/// and budget trace for the header lines.
fn pressure_grid(args: &Args, engine: &Engine) -> Result<(sched::GridSpec, String, String)> {
    let model = model_or_first(args, engine)?;
    let smoke = args.flag("smoke");
    let replicas = parse_replicas(args)?;
    let methods = args.get_or(
        "methods",
        if smoke {
            // Two registry compositions beyond the paper's columns: a
            // static FP16 method (accumulates OOMs) vs elasticity-only
            // (sheds batch) — the pressure contrast in miniature.
            "amp_dynamic,greedy_batch"
        } else if replicas > 1 {
            // A replicated sweep gets the elastic-replica composition
            // too: under the squeeze it sheds replicas before the
            // batch moves, with zero simulated OOMs.
            "fp32,amp_static,amp_dynamic,greedy_batch,tri_accel,tri_accel_replica"
        } else {
            "fp32,amp_static,amp_dynamic,greedy_batch,tri_accel"
        },
    );
    let explicit_seeds = args.get("seeds").is_some();
    let mut seeds = parse_seeds(args)?;
    if smoke && !explicit_seeds {
        seeds.truncate(1);
    }
    let steps: usize = args.parse_or("steps", if smoke { 24 } else { 60 })?;
    let epochs: usize = args.parse_or("epochs", if smoke { 1 } else { 3 })?;
    let total = (steps * epochs) as u64;
    // Default: budget ramps down to 55% across the middle half of the
    // run — late enough that every method trains at full budget first,
    // early enough that the squeeze dominates the tail. Degenerate
    // step budgets still get a valid (start < end) ramp.
    let ramp_start = total / 4;
    let ramp_end = ((3 * total) / 4).max(ramp_start + 1);
    let default_trace = format!("ramp:{ramp_start}:{ramp_end}:0.55");
    // `--scenario NAME` is sugar for `--trace scenario:NAME` — the
    // named adversarial pressure shapes (docs/MEMORY.md).
    let scenario = args.get("scenario").map(str::to_string);
    let explicit_trace = args.get("trace").map(str::to_string);
    let trace = match (scenario, explicit_trace) {
        (Some(_), Some(_)) => {
            anyhow::bail!("--scenario and --trace are mutually exclusive (pick one)")
        }
        (Some(name), None) => format!("scenario:{name}"),
        (None, Some(t)) => t,
        (None, None) => default_trace,
    };
    harness::validate_models(engine, &[model.as_str()])?;
    let keys: Vec<&str> = methods.split(',').collect();
    let budget = harness::quick_budget(steps, epochs);
    let tweak = move |cfg: &mut Config| {
        budget(cfg);
        cfg.replicas = replicas;
    };
    let spec = sched::pressure_spec(&model, &keys, &seeds, &trace, &tweak)?;
    Ok((spec, model, trace))
}

/// The VRAM-pressure scenario: sweep methods under a time-varying
/// budget trace (default: a ramp that squeezes the budget to 55% over
/// the middle half of the run). `--smoke` is the CI fast path — one
/// seed, two registry-composed methods, a short trace.
fn pressure(args: &Args) -> Result<()> {
    require_native(args)?;
    let engine = Engine::native();
    let (spec, model, trace) = pressure_grid(args, &engine)?;
    let opts = sched_opts(args)?;
    args.reject_unknown()?;
    let outcome = sched::run_grid(&spec, &opts)?;
    let rows = sched::report::pressure_rows(grid_ledger(&outcome)?)?;
    println!(
        "== VRAM pressure — {model} ({} seed(s)) ==",
        spec.cells.first().map(|c| c.seeds.len()).unwrap_or(0)
    );
    harness::print_pressure(&rows, &trace);
    print_outcome(&outcome);
    Ok(())
}

/// Build the adaptive-behaviour figure spec (also used by
/// `chaos --grid fig`); returns the spec plus the resolved model and
/// seed for the header line.
fn fig_grid(args: &Args, engine: &Engine) -> Result<(sched::GridSpec, String, u64)> {
    let model = model_or_first(args, engine)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let steps: usize = args.parse_or("steps", 60)?;
    let epochs: usize = args.parse_or("epochs", 3)?;
    harness::validate_models(engine, &[model.as_str()])?;
    let replicas = parse_replicas(args)?;
    let budget = harness::quick_budget(steps, epochs);
    let tweak = move |cfg: &mut Config| {
        budget(cfg);
        cfg.replicas = replicas;
    };
    Ok((sched::fig_spec(&model, seed, &tweak), model, seed))
}

fn fig(args: &Args) -> Result<()> {
    require_native(args)?;
    let engine = Engine::native();
    let (spec, model, seed) = fig_grid(args, &engine)?;
    let opts = sched_opts(args)?;
    args.reject_unknown()?;
    let outcome = sched::run_grid(&spec, &opts)?;
    // The figure series come back out of the persisted telemetry
    // stream — proof the JSONL events carry everything the plot needs.
    let t = sched::report::fig_series(&outcome.grid_dir, grid_ledger(&outcome)?)?;
    println!("== adaptive behaviour — {model} seed {seed} ==");
    println!("epoch, eff_score, fp16, bf16, fp32");
    for ((e, s), (_, f16, b16, f32_)) in t.epoch_eff.iter().zip(&t.mix_trace) {
        println!("{e}, {s:.3}, {f16:.2}, {b16:.2}, {f32_:.2}");
    }
    println!("batch trace (step, B):");
    for (st, b) in &t.batch_trace {
        println!("{st}, {b}");
    }
    print_outcome(&outcome);
    Ok(())
}

/// Default chaos fault plan: every fault kind fires at least once
/// under a fixed seed — transient telemetry IO errors on two jobs, a
/// transient ledger IO error, one panicking job, one simulated OOM
/// storm, and a torn final ledger record (simulated crash).
const DEFAULT_CHAOS_FAULTS: &str = "seed:7,io:2,ledger_io:1,panic:1,oom:1,torn:1";

/// `chaos`: run a grid under a deterministic fault plan, then prove
/// its report artifacts are bit-identical to a fault-free run of the
/// same grid. Torn-record faults abort `run_grid` mid-flight
/// (simulated process death); the in-process resume loop stands in
/// for the operator rerunning the command.
fn chaos(args: &Args) -> Result<()> {
    require_native(args)?;
    let engine = Engine::native();
    let grid = args.get_or("grid", "table1");
    let spec = match grid.as_str() {
        "table1" => table1_grid(args, &engine)?,
        "table2" => table2_grid(args, &engine)?.0,
        "pressure" => pressure_grid(args, &engine)?.0,
        "fig" => fig_grid(args, &engine)?.0,
        other => anyhow::bail!("--grid {other}: expected table1|table2|pressure|fig"),
    };
    let explicit_faults = args.get("faults").is_some();
    let mut opts = sched_opts(args)?;
    args.reject_unknown()?;
    let fspec = match opts.faults.take() {
        Some(f) => f,
        // `--faults none`: an explicit dry rehearsal with no injection.
        None if explicit_faults => faults::FaultSpec::default(),
        None => faults::FaultSpec::parse(DEFAULT_CHAOS_FAULTS)?,
    };
    println!("chaos: grid {grid}, fault plan [{}]", fspec.render());
    // The faulted run gets its own directory so the clean baseline
    // can't resume from its ledger (and vice versa).
    let mut chaos_opts = opts.clone();
    chaos_opts.out_dir = opts.out_dir.join("chaos");
    chaos_opts.faults = Some(fspec.clone());
    // Every torn record kills one run_grid call; +2 covers a retry
    // cushion while still failing fast on a non-converging loop.
    let max_restarts = fspec.torn + 2;
    let mut restarts = 0usize;
    let faulted = loop {
        match sched::run_grid(&spec, &chaos_opts) {
            Ok(o) => break o,
            Err(e) if format!("{e:#}").contains("injected") && restarts < max_restarts => {
                restarts += 1;
                println!("simulated crash #{restarts} ({e:#}) — resuming");
            }
            Err(e) => return Err(e),
        }
    };
    anyhow::ensure!(
        faulted.complete,
        "faulted run left {} job(s) quarantined — raise --retries above the fault \
         plan's hit counts to make every fault survivable",
        faulted.quarantined.len()
    );
    println!("faulted grid complete after {restarts} simulated crash(es); running the clean baseline");
    let clean = sched::run_grid(&spec, &opts)?;
    anyhow::ensure!(clean.complete, "clean baseline did not complete");
    anyhow::ensure!(!clean.artifacts.is_empty(), "clean baseline rendered no artifacts");
    let mut mismatches = 0usize;
    for a in &clean.artifacts {
        let name = a.file_name().context("artifact path has no file name")?;
        let twin = faulted.grid_dir.join(name);
        let clean_bytes = std::fs::read(a).with_context(|| a.display().to_string())?;
        let fault_bytes = std::fs::read(&twin).with_context(|| twin.display().to_string())?;
        if clean_bytes == fault_bytes {
            println!("identical: {}", name.to_string_lossy());
        } else {
            eprintln!("MISMATCH: {} differs from {}", twin.display(), a.display());
            mismatches += 1;
        }
    }
    anyhow::ensure!(
        mismatches == 0,
        "{mismatches} artifact(s) differ between the faulted and clean runs"
    );
    let log = faulted.grid_dir.join("faults.jsonl");
    let fired = std::fs::read_to_string(&log)
        .map(|s| s.lines().filter(|l| !l.trim().is_empty()).count())
        .unwrap_or(0);
    println!("fault log: {fired} fault(s) fired → {}", log.display());
    println!("chaos PASS: faulted artifacts are bit-identical to the fault-free run");
    print_outcome(&faulted);
    Ok(())
}

/// `trace`: telemetry-trace tooling for `mem_trace=replay:FILE`
/// (file format and determinism contract: docs/MEMORY.md).
///
/// * `--record (--events FILE | --grid DIR) --out FILE [--source S]`
///   converts a telemetry event stream into a versioned trace file —
///   the per-step `max_gb` ceiling the run observed — and prints the
///   canonical `replay:PATH#DIGEST` spec to feed back into
///   `pressure --trace` or `--set mem_trace=…`. `--grid DIR` records
///   from the grid's first events file (sorted job-key order).
/// * `--show FILE` prints a trace file's provenance and series.
/// * `--verify --a DIR --b DIR` compares two completed grid
///   directories for replay equivalence — wall-clock fields, line
///   CRCs, and config identity are normalized away; everything else
///   must match bit for bit. Exits nonzero on any mismatch.
fn trace_cmd(args: &Args) -> Result<()> {
    use tri_accel::memsim::tracefile::TraceFile;
    let record = args.flag("record");
    let show = args.get("show").map(PathBuf::from);
    let verify = args.flag("verify");
    if record {
        let events = args.get("events").map(PathBuf::from);
        let grid = args.get("grid").map(PathBuf::from);
        let out = PathBuf::from(args.get("out").context("--record needs --out FILE")?);
        let source_override = args.get("source").map(str::to_string);
        args.reject_unknown()?;
        let events_path = match (events, grid) {
            (Some(p), None) => p,
            (None, Some(dir)) => first_events_file(&dir)?,
            _ => anyhow::bail!("--record needs exactly one of --events FILE or --grid DIR"),
        };
        let text = std::fs::read_to_string(&events_path)
            .with_context(|| format!("reading {}", events_path.display()))?;
        let source = source_override.unwrap_or_else(|| events_path.display().to_string());
        let tf = TraceFile::from_events(&text, &source)?;
        tf.save(&out)?;
        println!(
            "recorded {} step(s) from {} → {}",
            tf.gb.len(),
            events_path.display(),
            out.display()
        );
        println!("replay spec: replay:{}#{:016x}", out.display(), tf.digest());
        return Ok(());
    }
    if let Some(path) = show {
        args.reject_unknown()?;
        let tf = TraceFile::load(&path)?;
        println!(
            "{}: {} step(s), source `{}`, digest {:016x}",
            path.display(),
            tf.gb.len(),
            tf.source,
            tf.digest()
        );
        const HEAD: usize = 16;
        for (i, gb) in tf.gb.iter().take(HEAD).enumerate() {
            println!("{i:>6}  {gb} GB");
        }
        if tf.gb.len() > HEAD {
            println!("     … {} more step(s)", tf.gb.len() - HEAD);
        }
        return Ok(());
    }
    if verify {
        let a = PathBuf::from(args.get("a").context("--verify needs --a GRID_DIR")?);
        let b = PathBuf::from(args.get("b").context("--verify needs --b GRID_DIR")?);
        args.reject_unknown()?;
        let rep = sched::replay::compare_grids(&a, &b)?;
        println!("{}", rep.render());
        anyhow::ensure!(rep.ok(), "grids are not replay-equivalent");
        return Ok(());
    }
    anyhow::bail!("trace: pick a mode — --record, --show FILE, or --verify --a DIR --b DIR")
}

/// The first events file (sorted job-key order) of a grid directory.
fn first_events_file(grid_dir: &Path) -> Result<PathBuf> {
    let events = grid_dir.join("events");
    let rd = std::fs::read_dir(&events)
        .with_context(|| format!("reading {} (not a grid directory?)", events.display()))?;
    let mut files: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
        .collect();
    files.sort();
    files
        .into_iter()
        .next()
        .with_context(|| format!("no .jsonl events under {}", events.display()))
}

/// `report`: re-render the markdown/JSON artifacts of completed grids
/// from their ledgers alone — no training runs. `--dir` targets one
/// grid directory; otherwise every `<out>/*/ledger.json` is rendered.
fn report(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "runs"));
    let dir = args.get("dir").map(PathBuf::from);
    args.reject_unknown()?;
    // With an explicit --dir, any failure is the user's answer; in
    // scan mode an incomplete grid (e.g. one killed mid-run, awaiting
    // resume) must not block rendering of the complete ones.
    let (dirs, explicit) = match dir {
        Some(d) => (vec![d], true),
        None => {
            let rd = std::fs::read_dir(&out).with_context(|| {
                format!("reading {} (run a grid first, or pass --dir)", out.display())
            })?;
            let mut v = Vec::new();
            for ent in rd {
                let p = ent?.path();
                if p.join("ledger.json").exists() {
                    v.push(p);
                }
            }
            v.sort();
            anyhow::ensure!(!v.is_empty(), "no grid ledgers under {}", out.display());
            (v, false)
        }
    };
    let mut rendered = 0usize;
    for d in dirs {
        let result = sched::Ledger::load(&d.join("ledger.json"))
            .and_then(|led| sched::report::render(&d, &led));
        match result {
            Ok(artifacts) => {
                rendered += 1;
                for a in artifacts {
                    println!("{}", a.display());
                }
            }
            Err(e) if !explicit => {
                eprintln!("skipping {}: {e:#}", d.display());
            }
            Err(e) => {
                return Err(anyhow::anyhow!("rendering {}: {e:#}", d.display()));
            }
        }
    }
    anyhow::ensure!(rendered > 0, "no grid could be rendered (see warnings above)");
    Ok(())
}
