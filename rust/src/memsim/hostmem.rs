//! Host-memory meter: real process RSS plus explicit arena/pool byte
//! accounting, behind the [`MemMeter`] trait with a deterministic fake
//! for tests.
//!
//! The meter is an *observational* budget source. Selected with
//! `--mem-source host`, its samples are taken only at control windows
//! and feed telemetry (`host_mem` events) alone; they never steer
//! policy decisions and never enter digests, goldens, or any sealed
//! artifact — all of those stay derived from the simulator. `/proc`
//! reads are environment data (D2-adjacent), so the read sites below
//! carry justified detlint pragmas; everything else in this module is
//! pure arithmetic.
//!
//! Samples can fail (a non-Linux host, a hardened procfs): `sample`
//! returns `Option` and the trainer just skips the event for that
//! window, so a missing `/proc` degrades to the default behavior
//! instead of erroring mid-run.

use super::GIB;

/// One point-in-time memory reading, in GiB.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemSample {
    /// Bytes currently attributed to this process (RSS + accounted).
    pub used_gb: f64,
    /// Budget ceiling the reading is judged against.
    pub max_gb: f64,
}

/// A budget-source meter sampled at control windows.
pub trait MemMeter: Send {
    /// Take one reading, or `None` if the backing source is
    /// unavailable (callers fall back to the simulator).
    fn sample(&mut self) -> Option<MemSample>;

    /// Stable source tag recorded in `host_mem` telemetry events.
    fn source(&self) -> &'static str;
}

/// Kernel page size assumed when converting `statm` pages to bytes.
/// 4 KiB is the fixed base page size on every x86-64 and aarch64
/// Linux kernel configuration we target; huge pages are still
/// reported by `statm` in base-page units.
const PAGE_BYTES: u64 = 4096;

/// Real host meter: `/proc/self/statm` RSS plus arena bytes the
/// runtime registers via [`HostMeter::account`].
#[derive(Debug)]
pub struct HostMeter {
    /// `MemTotal` ceiling captured once at construction.
    total_gb: f64,
    /// Pool/arena bytes explicitly registered by the runtime — memory
    /// reserved but not necessarily resident yet.
    accounted_bytes: u64,
}

impl HostMeter {
    /// Build a meter, capturing the host's `MemTotal` ceiling.
    /// Returns `None` when `/proc/meminfo` is missing or unreadable.
    pub fn new() -> Option<HostMeter> {
        // detlint: allow(d2) — host-meter reads environment data by design;
        // samples feed telemetry/observe only, never digests or goldens
        // (docs/MEMORY.md).
        let text = std::fs::read_to_string("/proc/meminfo").ok()?;
        let total_kb = meminfo_total_kb(&text)?;
        Some(HostMeter { total_gb: total_kb as f64 * 1024.0 / GIB, accounted_bytes: 0 })
    }

    /// Register additional arena/pool bytes (reserved allocations the
    /// kernel may not count as resident yet).
    pub fn account(&mut self, bytes: u64) {
        self.accounted_bytes = self.accounted_bytes.saturating_add(bytes);
    }

    /// Release previously accounted bytes.
    pub fn release(&mut self, bytes: u64) {
        self.accounted_bytes = self.accounted_bytes.saturating_sub(bytes);
    }

    /// Currently accounted arena/pool bytes.
    pub fn accounted_bytes(&self) -> u64 {
        self.accounted_bytes
    }
}

impl MemMeter for HostMeter {
    fn sample(&mut self) -> Option<MemSample> {
        // detlint: allow(d2) — host-meter reads environment data by design;
        // samples feed telemetry/observe only, never digests or goldens
        // (docs/MEMORY.md).
        let text = std::fs::read_to_string("/proc/self/statm").ok()?;
        let rss_pages = statm_resident_pages(&text)?;
        let used = rss_pages.saturating_mul(PAGE_BYTES).saturating_add(self.accounted_bytes);
        Some(MemSample { used_gb: used as f64 / GIB, max_gb: self.total_gb })
    }

    fn source(&self) -> &'static str {
        "host"
    }
}

/// Parse the resident-pages field (second column) of `/proc/self/statm`.
fn statm_resident_pages(text: &str) -> Option<u64> {
    text.split_whitespace().nth(1)?.parse().ok()
}

/// Parse the `MemTotal:` line (kB) out of `/proc/meminfo`.
fn meminfo_total_kb(text: &str) -> Option<u64> {
    let line = text.lines().find(|l| l.starts_with("MemTotal:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Deterministic meter for tests: replays a fixed sample series,
/// holding the last sample once exhausted.
#[derive(Debug)]
pub struct FakeMeter {
    series: Vec<MemSample>,
    next: usize,
}

impl FakeMeter {
    /// A fake that yields `series` in order, then repeats the final
    /// sample forever. An empty series yields `None` every time
    /// (models a meter whose backing source is unavailable).
    pub fn new(series: Vec<MemSample>) -> FakeMeter {
        FakeMeter { series, next: 0 }
    }
}

impl MemMeter for FakeMeter {
    fn sample(&mut self) -> Option<MemSample> {
        let last = self.series.len().checked_sub(1)?;
        let s = self.series[self.next.min(last)];
        self.next += 1;
        Some(s)
    }

    fn source(&self) -> &'static str {
        "fake"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn statm_second_field_is_resident_pages() {
        assert_eq!(statm_resident_pages("12345 678 90 1 0 2 0"), Some(678));
        assert_eq!(statm_resident_pages("12345"), None);
        assert_eq!(statm_resident_pages("a b"), None);
    }

    #[test]
    fn meminfo_total_line_is_parsed() {
        let text = "MemFree:  1 kB\nMemTotal:       16303492 kB\n";
        assert_eq!(meminfo_total_kb(text), Some(16_303_492));
        assert_eq!(meminfo_total_kb("SwapTotal: 2 kB\n"), None);
    }

    #[test]
    fn fake_meter_replays_then_holds_the_last_sample() {
        let a = MemSample { used_gb: 1.0, max_gb: 8.0 };
        let b = MemSample { used_gb: 2.0, max_gb: 8.0 };
        let mut m = FakeMeter::new(vec![a, b]);
        assert_eq!(m.sample(), Some(a));
        assert_eq!(m.sample(), Some(b));
        assert_eq!(m.sample(), Some(b), "holds past the end");
        assert_eq!(m.source(), "fake");
    }

    #[test]
    fn empty_fake_meter_models_an_unavailable_source() {
        let mut m = FakeMeter::new(Vec::new());
        assert_eq!(m.sample(), None);
        assert_eq!(m.sample(), None);
    }

    #[test]
    fn host_meter_accounting_saturates() {
        // Exercise the arena accounting without touching /proc.
        let mut m = HostMeter { total_gb: 8.0, accounted_bytes: 0 };
        m.account(1024);
        m.account(u64::MAX);
        assert_eq!(m.accounted_bytes(), u64::MAX, "add saturates");
        m.release(u64::MAX);
        m.release(1);
        assert_eq!(m.accounted_bytes(), 0, "release saturates at zero");
        assert_eq!(m.source(), "host");
    }

    #[test]
    fn host_meter_samples_on_linux() {
        // On any Linux host /proc is available; elsewhere both
        // constructors degrade to None and the test is vacuous.
        if let Some(mut m) = HostMeter::new() {
            let s = m.sample().expect("statm readable when meminfo was");
            assert!(s.used_gb > 0.0, "a live process has resident pages");
            assert!(s.max_gb >= s.used_gb, "RSS cannot exceed MemTotal");
            m.account(2 * 1024 * 1024 * 1024);
            let s2 = m.sample().expect("statm still readable");
            assert!(s2.used_gb > s.used_gb + 1.9, "accounted bytes are added");
        }
    }
}
