use std::collections::BTreeMap;

fn table() -> BTreeMap<String, u64> {
    BTreeMap::new()
}
