"""`sr_qdq` — stochastic-rounding precision emulation (extension kernel).

The paper's §4.5 points at "low-rank or learned approximations" and broader
numeric work as future directions; stochastic rounding is the standard
next step beyond round-to-nearest for low-precision training (Gupta et
al. 2015), so we ship it as a first-class ablation: the Rust config can
flip `rounding = "stochastic"` and the BF16 leg of every qdq becomes
unbiased.

Noise is an explicit uniform-[0,1) input (threaded from the Rust side's
seeded RNG via the train graph) — the kernel stays deterministic and
replayable, matching the 3-seed protocol.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 128 * 1024


def _sr_kernel(code_ref, x_ref, noise_ref, o_ref):
    x = x_ref[...]
    noise = noise_ref[...]
    code = code_ref[0]

    bits = x.view(jnp.uint32)
    lo_bits = bits & jnp.uint32(0xFFFF0000)
    lo = lo_bits.view(jnp.float32)
    hi = (lo_bits + jnp.uint32(0x00010000)).view(jnp.float32)
    span = hi - lo
    frac = jnp.where(span != 0, (x - lo) / jnp.where(span != 0, span, 1.0), 0.0)
    sr_b16 = jnp.where(noise < frac, hi, lo)
    sr_b16 = jnp.where(jnp.isfinite(x), sr_b16, x)

    f16 = x.astype(jnp.float16).astype(jnp.float32)
    o_ref[...] = jnp.where(
        code == ref.FP16, f16, jnp.where(code == ref.BF16, sr_b16, x)
    )


@jax.custom_vjp
def sr_qdq(x: jnp.ndarray, noise: jnp.ndarray, code: jnp.ndarray) -> jnp.ndarray:
    """Stochastically-rounded qdq. Matches `ref.sr_qdq_ref` exactly."""
    return _apply(x, noise, code)


def _apply(x, noise, code):
    shape = x.shape
    x_flat = x.astype(jnp.float32).reshape(-1)
    noise_flat = noise.astype(jnp.float32).reshape(-1)
    n = x_flat.shape[0]
    pad = (-n) % BLOCK if n > BLOCK else 0
    if pad:
        z = jnp.zeros((pad,), jnp.float32)
        x_flat = jnp.concatenate([x_flat, z])
        noise_flat = jnp.concatenate([noise_flat, z])
    np_ = x_flat.shape[0]
    block = BLOCK if np_ >= BLOCK else np_
    out = pl.pallas_call(
        _sr_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(code.reshape(1).astype(jnp.int32), x_flat, noise_flat)
    if pad:
        out = out[:n]
    return out.reshape(shape)


def _fwd(x, noise, code):
    return _apply(x, noise, code), code


def _bwd(code, g):
    # Straight-through: SR is unbiased, so identity is the right estimator
    # (round-to-nearest on the cotangent would re-bias it).
    return g, None, None


sr_qdq.defvjp(_fwd, _bwd)
