fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>() // detlint: ordered — sequential sum in slice order.
}

fn peak(xs: &[f32]) -> f32 {
    xs.iter().copied().fold(f32::MIN, f32::max)
}
