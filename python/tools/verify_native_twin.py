"""Numpy twin of the Rust native compute core, validated against the
repo's JAX reference train graph.

The twin mirrors the structure of `rust/src/runtime/native/` after the
PR-2 rewrite — fused-qdq im2col + GEMM convolution, chunked
ordered-reduction weight gradients (`gemm_at_b`), col2im input
gradients, f64-accumulated BN statistics, mp_matmul-style dense VJP —
and a full train step is compared against
`python/compile/train_graph.make_train_step` (loss, per-parameter
gradients, BN state, per-layer grad stats, overflow flag).

Run whenever the native ops change and no Rust toolchain is available
(see .claude/skills/verify/SKILL.md):

    python3 python/tools/verify_native_twin.py

Expected: "TWIN == JAX REFERENCE: all scenarios pass". The all-fp16
huge-loss-scale scenario is held to the repo's statistical fp16
standard (same loss / overflow flag / grad-stat scale) because
elementwise equality across accumulation orders is undefined on fp16
quantization cliffs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax
import jax.numpy as jnp

from compile.models import tiny_cnn
from compile import train_graph

FP16, BF16, FP32 = 0, 1, 2
BN_EPS, BN_MOM = 1e-5, 0.1
CH = [16, 32, 64]
DIMS = [32, 16, 8]
FEAT = 64


# ---- qdq (mirrors rust/src/runtime/native/qdq.rs) -------------------------
def qdq(x, code):
    x = np.asarray(x, np.float32)
    if code == FP16:
        return x.astype(np.float16).astype(np.float32)
    if code == BF16:
        bits = np.ascontiguousarray(x).view(np.uint32)
        rnd = ((bits >> 16) & 1) + np.uint32(0x7FFF)
        out = ((bits + rnd) & np.uint32(0xFFFF0000)).view(np.float32)
        return np.where(np.isnan(x), x, out)
    return x


# ---- im2col / col2im (mirror gemm.rs layouts) -----------------------------
def im2col_qdq(x, n, h, w, cin, code):
    k9 = 9 * cin
    cols = np.zeros((n * h * w, k9), np.float32)
    xq = qdq(x.reshape(n, h, w, cin), code)
    for ky in range(3):
        for kx in range(3):
            c0 = (ky * 3 + kx) * cin
            for bi in range(n):
                for oy in range(h):
                    iy = oy + ky - 1
                    if iy < 0 or iy >= h:
                        continue
                    for ox in range(w):
                        ix = ox + kx - 1
                        if ix < 0 or ix >= w:
                            continue
                        cols[(bi * h + oy) * w + ox, c0:c0 + cin] = xq[bi, iy, ix]
    return cols


def col2im(dcols, n, h, w, cin):
    dx = np.zeros((n, h, w, cin), np.float32)
    k9 = 9 * cin
    for ky in range(3):
        for kx in range(3):
            c0 = (ky * 3 + kx) * cin
            for bi in range(n):
                for iy in range(h):
                    oy = iy + 1 - ky
                    if oy < 0 or oy >= h:
                        continue
                    for ix in range(w):
                        ox = ix + 1 - kx
                        if ox < 0 or ox >= w:
                            continue
                        dx[bi, iy, ix] += dcols[(bi * h + oy) * w + ox, c0:c0 + cin]
    return dx


def gemm_at_b_chunked(a, b, chunk=1024):
    """AᵀB via fixed m-chunk partials + ordered reduction (gemm.rs)."""
    m = a.shape[0]
    acc = np.zeros((a.shape[1], b.shape[1]), np.float32)
    for c in range((m + chunk - 1) // chunk):
        lo, hi = c * chunk, min((c + 1) * chunk, m)
        part = (a[lo:hi].T @ b[lo:hi]).astype(np.float32)
        acc = (acc + part).astype(np.float32)
    return acc


# ---- layer ops (mirror ops.rs *_into variants) ----------------------------
def bn_fwd(x2d, gamma, beta, rm, rv):
    rows, _ = x2d.shape
    mean = (x2d.astype(np.float64).sum(0) / rows).astype(np.float32)
    d = (x2d - mean).astype(np.float32).astype(np.float64)
    var = ((d * d).sum(0) / rows).astype(np.float32)
    nrm = ((1 - BN_MOM) * rm + BN_MOM * mean).astype(np.float32)
    nrv = ((1 - BN_MOM) * rv + BN_MOM * var).astype(np.float32)
    inv = (1.0 / np.sqrt(var + BN_EPS)).astype(np.float32)
    out = ((x2d - mean) * inv * gamma + beta).astype(np.float32)
    return out, nrm, nrv, mean, inv


def bn_bwd(x2d, g2d, gamma, mean, inv):
    rows, _ = x2d.shape
    gv = g2d.astype(np.float64)
    xhat64 = ((x2d - mean) * inv).astype(np.float32).astype(np.float64)
    dbeta = gv.sum(0).astype(np.float32)
    dgamma = (gv * xhat64).sum(0).astype(np.float32)
    nf = np.float32(rows)
    xhat = ((x2d - mean) * inv).astype(np.float32)
    coeff = (gamma * inv / nf).astype(np.float32)
    dx = (coeff * (nf * g2d - dbeta - xhat * dgamma)).astype(np.float32)
    return dx, dgamma, dbeta


def maxpool(x4):
    n, h, w, c = x4.shape
    ho, wo = h // 2, w // 2
    win = x4.reshape(n, ho, 2, wo, 2, c).transpose(0, 1, 3, 2, 4, 5).reshape(n, ho, wo, 4, c)
    arg = np.argmax(win, axis=3)  # first max wins, like the Rust kernel
    out = np.take_along_axis(win, arg[:, :, :, None, :], axis=3)[:, :, :, 0, :]
    return out, arg


def maxpool_bwd(g4, arg, n, h, w, c):
    ho, wo = h // 2, w // 2
    dwin = np.zeros((n, ho, wo, 4, c), np.float32)
    np.put_along_axis(dwin, arg[:, :, :, None, :], g4[:, :, :, None, :], axis=3)
    return dwin.reshape(n, ho, wo, 2, 2, c).transpose(0, 1, 3, 2, 4, 5).reshape(n, h, w, c)


def softmax_ce(logits, y):
    n, _ = logits.shape
    m = logits.max(1, keepdims=True)
    z = np.exp((logits - m).astype(np.float32)).sum(1, keepdims=True).astype(np.float32)
    logz = np.log(z) + m
    loss = np.float32(
        np.float64((logz[:, 0] - logits[np.arange(n), y]).astype(np.float64).sum()) / n
    )
    p = (np.exp(logits - m) / z).astype(np.float32)
    d = p.copy()
    d[np.arange(n), y] -= 1.0
    d = (d / np.float32(n)).astype(np.float32)
    return loss, int((logits.argmax(1) == y).sum()), d


LAYER_OF = [0, -1, -1, 1, -1, -1, 2, -1, -1, 3, -1]


def twin_step(params, state, x, y, codes, loss_scale):
    """One train step with the PR-2 Rust pipeline's structure."""
    n = y.shape[0]
    cache = []
    cur = x.astype(np.float32)
    cin = 3
    new_state = []
    for li in range(3):
        dim, cout, code = DIMS[li], CH[li], codes[li]
        cols = im2col_qdq(cur.reshape(-1), n, dim, dim, cin, code)
        wq = qdq(params[li * 3], code).reshape(9 * cin, cout)
        conv = (cols @ wq).astype(np.float32)
        bnout, nrm, nrv, mean, inv = bn_fwd(
            conv, params[li * 3 + 1], params[li * 3 + 2], state[li * 2], state[li * 2 + 1]
        )
        new_state += [nrm, nrv]
        r = np.maximum(bnout, 0.0).reshape(n, dim, dim, cout)
        if li < 2:
            nxt, arg = maxpool(r)
        else:
            nxt = (r.reshape(n, dim * dim, cout).astype(np.float64).sum(1) / (dim * dim))
            nxt = nxt.astype(np.float32)
            arg = None
        cache.append((cols, wq, conv, mean, inv, bnout, arg))
        cur = nxt
        cin = cout

    code = codes[3]
    head_xq = qdq(cur.reshape(n, FEAT), code)
    head_wq = qdq(params[9], code)
    logits = (params[10][None, :] + head_xq @ head_wq).astype(np.float32)
    loss, correct, dlogits = softmax_ce(logits, y)

    grads = [None] * 11
    g_logits = (dlogits * np.float32(loss_scale)).astype(np.float32)
    gq = qdq(g_logits, code)
    grads[9] = gemm_at_b_chunked(head_xq, gq)
    db = np.zeros_like(params[10])
    for bi in range(n):  # raw cotangent, bi-major (backward() in tiny_cnn.rs)
        db = (db + g_logits[bi]).astype(np.float32)
    grads[10] = db
    g = (gq @ head_wq.T).astype(np.float32)
    for li in (2, 1, 0):
        dim, cout, code = DIMS[li], CH[li], codes[li]
        cin_l = 3 if li == 0 else CH[li - 1]
        rows = n * dim * dim
        cols, wq, conv, mean, inv, bnout, arg = cache[li]
        if li == 2:
            gs = (np.repeat(g[:, None, :], dim * dim, 1) / np.float32(dim * dim))
            gs = gs.reshape(rows, cout).astype(np.float32)
        else:
            gs = maxpool_bwd(g, arg, n, dim, dim, cout).reshape(rows, cout)
        gs = np.where(bnout <= 0.0, np.float32(0.0), gs).astype(np.float32)
        dxbn, dgamma, dbeta = bn_bwd(conv, gs, params[li * 3 + 1], mean, inv)
        grads[li * 3] = qdq(gemm_at_b_chunked(cols, dxbn), code)
        grads[li * 3 + 1] = dgamma
        grads[li * 3 + 2] = dbeta
        if li > 0:  # conv1's input gradient is skipped in the Rust core too
            dcols = (dxbn @ wq.T).astype(np.float32)
            g = qdq(col2im(dcols, n, dim, dim, cin_l), code)

    inv_s = np.float32(1.0 / loss_scale)
    grads = [(gg * inv_s).astype(np.float32) for gg in grads]
    overflow = any(not np.all(np.isfinite(gg)) for gg in grads)
    gv, gn = [], []
    for layer in range(4):
        s = sq = 0.0
        cnt = 0
        for pi, lidx in enumerate(LAYER_OF):
            if lidx != layer:
                continue
            gg = grads[pi].astype(np.float64).reshape(-1)
            s += gg.sum()
            sq += (gg * gg).sum()
            cnt += gg.size
        mean = s / max(cnt, 1)
        raw = sq / max(cnt, 1) - mean * mean
        gv.append(np.float32(raw if np.isnan(raw) else max(raw, 0.0)))
        gn.append(np.float32(sq))
    return loss, correct, grads, new_state, overflow, gv, gn


def main():
    model = tiny_cnn.build(10, seed=0)
    step = jax.jit(train_graph.make_train_step(model))
    rng = np.random.default_rng(7)
    n = 8
    x = rng.standard_normal((n, 32, 32, 3)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    params = [np.asarray(p) for p in model.params]
    mom = [np.zeros_like(p) for p in params]
    state = [np.asarray(s) for s in model.state]

    scenarios = [
        ([FP32] * 4, 1.0, "fp32/scale1"),
        ([FP32] * 4, 1024.0, "fp32/scale1024"),
        ([FP16, BF16, FP32, BF16], 256.0, "mixed/scale256"),
        ([FP16] * 4, 65536.0, "fp16/scale64k"),
        ([FP16] * 4, 1e30, "fp16/overflow"),
    ]
    for codes, scale, tag in scenarios:
        out = step(
            tuple(jnp.asarray(p) for p in params),
            tuple(jnp.asarray(m) for m in mom),
            tuple(jnp.asarray(s) for s in state),
            jnp.asarray(x),
            jnp.asarray(y),
            jnp.asarray(codes, jnp.int32),
            jnp.ones(4, jnp.float32),
            jnp.float32(0.05),
            jnp.float32(scale),
            jnp.float32(5e-4),
        )
        jp, jm, js, jloss, jcorr, jgv, jgn, jovf = out
        with np.errstate(over="ignore", invalid="ignore"):
            tl, tc, tg, tns, tovf, tgv, tgn = twin_step(params, state, x, y, codes, scale)
        print(
            f"== {tag}: jax loss {float(jloss):.6f} twin {float(tl):.6f} "
            f"correct {int(jcorr)}/{tc} overflow {int(jovf)}/{int(tovf)}"
        )
        assert abs(float(jloss) - float(tl)) < 2e-4 * max(1.0, abs(float(jloss)))
        assert int(jcorr) == tc and int(jovf) == int(tovf)
        if tag == "fp16/overflow":
            assert tovf and np.allclose(np.asarray(jp[0]), params[0]), "params must hold"
            print("   overflow contract OK")
            continue
        if tag == "fp16/scale64k":
            # Quantization-cliff regime: statistical agreement only (the
            # standard integration_runtime.rs applies to fp16).
            for layer in range(4):
                a, b = float(np.asarray(jgv)[layer]), float(tgv[layer])
                assert max(a / b, b / a) < 2.0, f"grad_var off-scale L{layer}: {a} vs {b}"
            print("   fp16 statistical check OK")
            continue
        # mom was zero, so the updated momentum IS g + wd·p — recover the
        # reference gradient from the optimizer output and compare.
        for pi in range(11):
            jgrad = np.asarray(jm[pi]).reshape(-1) - 5e-4 * params[pi].reshape(-1)
            rel = (np.abs(jgrad - tg[pi].reshape(-1)) / np.maximum(np.abs(jgrad), 1e-4)).max()
            assert rel < 2e-2, f"{tag} param {pi}: max rel grad diff {rel}"
        for layer in range(4):
            a, b = float(np.asarray(jgv)[layer]), float(tgv[layer])
            assert abs(a - b) < 2e-2 * max(abs(a), 1e-9), f"grad_var L{layer}: {a} vs {b}"
            a, b = float(np.asarray(jgn)[layer]), float(tgn[layer])
            assert abs(a - b) < 2e-2 * max(abs(a), 1e-9), f"grad_norm L{layer}: {a} vs {b}"
        for si in range(6):
            assert np.abs(np.asarray(js[si]) - tns[si]).max() < 1e-4, f"bn state {si}"
        print("   grads/stats/state OK")
    print("TWIN == JAX REFERENCE: all scenarios pass")


if __name__ == "__main__":
    main()
