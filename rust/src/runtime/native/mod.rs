//! The native backend: a pure-Rust executor for the manifest entry
//! points, needing no artifacts, no Python, and no native deps.
//!
//! It ships its own built-in manifest (the same schema
//! `python/compile/aot.py` emits), so `Engine::native()` works from a
//! fresh checkout. Currently implements the `tiny_cnn` architecture —
//! the CI-speed model the integration tests and quickstart use; larger
//! models stay on the artifact-driven PJRT backend.
//!
//! Compute core (see the "Performance" section of the README):
//! * [`gemm`] — cache-blocked, register-tiled f32 GEMM plus the
//!   im2col/col2im pack stage (with fused fp16/bf16 qdq); conv and
//!   dense both execute on it.
//! * [`pool`] — deterministic scoped-thread worker pool: fixed work
//!   chunks + ordered reductions, so results are bit-identical for any
//!   `TRIACCEL_THREADS` value.
//! * [`arena`] — scratch-buffer free list; a warm train step performs
//!   zero buffer allocations.
//! All three meet in [`Exec`], the per-backend execution context.

pub mod arena;
pub mod gemm;
pub mod ops;
pub mod pool;
pub mod qdq;
mod tiny_cnn;

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use self::arena::Arena;
use self::pool::Pool;
use super::backend::{Backend, ModelState};
use super::{Batch, EvalResult, StepCtrl, TrainOutputs};
use crate::manifest::{Manifest, ModelEntry};

/// Execution context for the native compute core: the deterministic
/// worker pool plus the zero-alloc scratch arena. One `Exec` serializes
/// one stream of steps; the backend keeps it behind a mutex so the
/// `Backend` trait's `&self` entry points stay thread-safe.
#[derive(Debug)]
pub struct Exec {
    pub pool: Pool,
    pub arena: Arena,
}

impl Exec {
    /// Context with an explicit worker count.
    pub fn new(threads: usize) -> Exec {
        Exec { pool: Pool::new(threads), arena: Arena::new() }
    }

    /// Context honouring `TRIACCEL_THREADS` (default: machine
    /// parallelism capped at 8).
    pub fn from_env() -> Exec {
        Exec { pool: Pool::from_env(), arena: Arena::new() }
    }
}

/// The built-in manifest served by [`builtin_manifest`]. Layer/param
/// accounting matches `python/compile/models/tiny_cnn.py` exactly
/// (3×3 convs at 16/32/64 channels on 32×32 inputs, dense head).
const BUILTIN_MANIFEST: &str = r#"{
  "precision_codes": {"fp16": 0, "bf16": 1, "fp32": 2},
  "models": {
    "tiny_cnn_c10": {
      "model": "tiny_cnn",
      "num_classes": 10,
      "num_layers": 4,
      "param_count": 24346,
      "layers": [
        {"name": "conv1", "kind": "conv", "param_elems": 432, "act_elems": 16384, "flops": 442368},
        {"name": "conv2", "kind": "conv", "param_elems": 4608, "act_elems": 8192, "flops": 1179648},
        {"name": "conv3", "kind": "conv", "param_elems": 18432, "act_elems": 4096, "flops": 1179648},
        {"name": "head", "kind": "dense", "param_elems": 640, "act_elems": 10, "flops": 640}
      ],
      "params": [
        {"name": "conv1/w", "shape": [3, 3, 3, 16], "layer_idx": 0, "elems": 432},
        {"name": "bn1/gamma", "shape": [16], "layer_idx": -1, "elems": 16},
        {"name": "bn1/beta", "shape": [16], "layer_idx": -1, "elems": 16},
        {"name": "conv2/w", "shape": [3, 3, 16, 32], "layer_idx": 1, "elems": 4608},
        {"name": "bn2/gamma", "shape": [32], "layer_idx": -1, "elems": 32},
        {"name": "bn2/beta", "shape": [32], "layer_idx": -1, "elems": 32},
        {"name": "conv3/w", "shape": [3, 3, 32, 64], "layer_idx": 2, "elems": 18432},
        {"name": "bn3/gamma", "shape": [64], "layer_idx": -1, "elems": 64},
        {"name": "bn3/beta", "shape": [64], "layer_idx": -1, "elems": 64},
        {"name": "head/w", "shape": [64, 10], "layer_idx": 3, "elems": 640},
        {"name": "head/b", "shape": [10], "layer_idx": -1, "elems": 10}
      ],
      "state_shapes": [[16], [16], [32], [32], [64], [64]],
      "train_buckets": [16, 32, 64, 96, 128],
      "eval_buckets": [16, 128],
      "curv_batch": 32,
      "artifacts": {}
    },
    "tiny_cnn_c100": {
      "model": "tiny_cnn",
      "num_classes": 100,
      "num_layers": 4,
      "param_count": 30196,
      "layers": [
        {"name": "conv1", "kind": "conv", "param_elems": 432, "act_elems": 16384, "flops": 442368},
        {"name": "conv2", "kind": "conv", "param_elems": 4608, "act_elems": 8192, "flops": 1179648},
        {"name": "conv3", "kind": "conv", "param_elems": 18432, "act_elems": 4096, "flops": 1179648},
        {"name": "head", "kind": "dense", "param_elems": 6400, "act_elems": 100, "flops": 6400}
      ],
      "params": [
        {"name": "conv1/w", "shape": [3, 3, 3, 16], "layer_idx": 0, "elems": 432},
        {"name": "bn1/gamma", "shape": [16], "layer_idx": -1, "elems": 16},
        {"name": "bn1/beta", "shape": [16], "layer_idx": -1, "elems": 16},
        {"name": "conv2/w", "shape": [3, 3, 16, 32], "layer_idx": 1, "elems": 4608},
        {"name": "bn2/gamma", "shape": [32], "layer_idx": -1, "elems": 32},
        {"name": "bn2/beta", "shape": [32], "layer_idx": -1, "elems": 32},
        {"name": "conv3/w", "shape": [3, 3, 32, 64], "layer_idx": 2, "elems": 18432},
        {"name": "bn3/gamma", "shape": [64], "layer_idx": -1, "elems": 64},
        {"name": "bn3/beta", "shape": [64], "layer_idx": -1, "elems": 64},
        {"name": "head/w", "shape": [64, 100], "layer_idx": 3, "elems": 6400},
        {"name": "head/b", "shape": [100], "layer_idx": -1, "elems": 100}
      ],
      "state_shapes": [[16], [16], [32], [32], [64], [64]],
      "train_buckets": [16, 32, 64, 96, 128],
      "eval_buckets": [16, 128],
      "curv_batch": 32,
      "artifacts": {}
    }
  }
}"#;

/// The manifest the native backend serves (no `artifacts/` needed).
pub fn builtin_manifest() -> Manifest {
    Manifest::parse(BUILTIN_MANIFEST, Path::new("builtin"))
        .expect("built-in manifest is valid by construction")
}

/// Pure-Rust executor over the high-throughput native compute core.
#[derive(Debug)]
pub struct NativeBackend {
    exec: Mutex<Exec>,
}

impl NativeBackend {
    /// Backend honouring `TRIACCEL_THREADS`.
    pub fn new() -> NativeBackend {
        NativeBackend { exec: Mutex::new(Exec::from_env()) }
    }

    /// Backend with an explicit worker count (test/bench hook — avoids
    /// racing on the process environment).
    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend { exec: Mutex::new(Exec::new(threads)) }
    }

    /// Worker count this backend computes with.
    pub fn threads(&self) -> usize {
        self.exec.lock().unwrap().pool.threads()
    }
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native-cpu"
    }

    fn supports(&self, entry: &ModelEntry) -> bool {
        entry.model == "tiny_cnn"
    }

    fn init(&self, entry: &ModelEntry, seed: i32) -> Result<ModelState> {
        tiny_cnn::init(entry, seed)
    }

    fn train_step(
        &self,
        entry: &ModelEntry,
        st: &mut ModelState,
        batch: &Batch,
        ctrl: &StepCtrl,
    ) -> Result<TrainOutputs> {
        let mut ex = self.exec.lock().unwrap();
        tiny_cnn::train_step(&mut ex, entry, st, batch, ctrl)
    }

    fn eval_batch(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        codes: &[i32],
    ) -> Result<EvalResult> {
        let mut ex = self.exec.lock().unwrap();
        tiny_cnn::eval_batch(&mut ex, entry, st, batch, codes)
    }

    fn curv_step(
        &self,
        entry: &ModelEntry,
        st: &ModelState,
        batch: &Batch,
        probes: &mut [Vec<f32>],
        codes: &[i32],
    ) -> Result<Vec<f32>> {
        let mut ex = self.exec.lock().unwrap();
        tiny_cnn::curv_step(&mut ex, entry, st, batch, probes, codes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_manifest_parses_and_accounts() {
        let m = builtin_manifest();
        let e = m.model("tiny_cnn_c10").unwrap();
        assert_eq!(e.num_layers, 4);
        assert_eq!(e.param_count, 24346);
        assert_eq!(e.quantizable_elems(), 432 + 4608 + 18432 + 640);
        assert_eq!(e.act_elems_per_sample(), 16384 + 8192 + 4096 + 10);
        assert_eq!(e.state_elems(), 2 * (16 + 32 + 64));
        assert!(e.train_buckets.contains(&96));
        let e100 = m.model("tiny_cnn_c100").unwrap();
        assert_eq!(e100.num_classes, 100);
        assert_eq!(e100.param_count, 30196);
    }

    #[test]
    fn with_threads_pins_the_worker_count() {
        assert_eq!(NativeBackend::with_threads(3).threads(), 3);
        assert_eq!(NativeBackend::with_threads(0).threads(), 1, "clamped");
        assert!(NativeBackend::new().threads() >= 1);
    }

    #[test]
    fn backend_supports_tiny_cnn_only() {
        let m = builtin_manifest();
        let b = NativeBackend::new();
        assert!(b.supports(m.model("tiny_cnn_c10").unwrap()));
        let mut other = m.model("tiny_cnn_c10").unwrap().clone();
        other.model = "resnet18".into();
        assert!(!b.supports(&other));
        assert_eq!(b.name(), "native-cpu");
    }
}
