//! §3.1 Precision-Adaptive Updates.
//!
//! Per layer l the controller maintains an EMA of the gradient variance,
//!
//! ```text
//! v_l(t) = β·v_l(t-1) + (1-β)·Var[∇_l(t)]
//! ```
//!
//! and at each control window selects
//!
//! ```text
//! p_l(t) = FP16   if v_l < τ_low
//!          BF16   if τ_low ≤ v_l < τ_high
//!          FP32   if v_l ≥ τ_high
//! ```
//!
//! Two practical mechanisms on top of the paper's rule:
//!
//! * **Hysteresis** — a layer only moves one precision rung per control
//!   window and the thresholds carry a relative dead-band, so the policy
//!   does not chatter when v_l sits on a boundary (chatter would defeat
//!   the paper's "negligible overhead" claim by thrashing compute copies).
//! * **Auto-thresholding** — when `auto_threshold` is set, τ_low/τ_high
//!   are (re)calibrated from the observed cross-layer variance
//!   distribution (percentiles), reproducing the abstract's "automatic
//!   optimization without manual hyperparameter tuning".
//!
//! Curvature promotion (§3.2 "precision promotion") enters through
//! [`PrecisionController::promote`]: promoted layers are pinned to FP32
//! for a configurable number of windows regardless of variance.
//!
//! Two [`PrecisionPolicy`](super::PrecisionPolicy) impls live here:
//! [`PrecisionController`] (the adaptive rule above) and
//! [`PinnedPrecision`] (a constant code vector — the FP32 / static-AMP
//! baselines and the precision-off ablation).

use crate::manifest::{BF16, FP16, FP32};
use crate::util::stats::Ema;

use super::{ckpt_lookup, ckpt_lookup_opt, PrecisionPolicy};

/// Relative dead-band applied around τ when deciding to *leave* the
/// current precision (enter thresholds are the paper's exact rule).
const HYSTERESIS: f64 = 0.2;

/// How many control windows a curvature promotion pins a layer to FP32.
const PROMOTION_WINDOWS: u32 = 2;

#[derive(Debug, Clone)]
pub struct PrecisionConfig {
    pub beta: f64,
    pub tau_low: f64,
    pub tau_high: f64,
    pub auto_threshold: bool,
    /// Default code before any statistics exist (paper: "BF16 is the
    /// default precision mode unless otherwise noted").
    pub default_code: i32,
}

impl PrecisionConfig {
    pub fn from_cfg(cfg: &crate::config::Config) -> PrecisionConfig {
        PrecisionConfig {
            beta: cfg.beta,
            tau_low: cfg.tau_low,
            tau_high: cfg.tau_high,
            auto_threshold: cfg.auto_threshold,
            default_code: BF16,
        }
    }
}

pub struct PrecisionController {
    cfg: PrecisionConfig,
    /// EMA of Var[∇_l] per layer.
    vars: Vec<Ema>,
    codes: Vec<i32>,
    /// Remaining FP32-pin windows per layer from curvature promotion.
    promoted: Vec<u32>,
    tau_low: f64,
    tau_high: f64,
    calibrated: bool,
    /// Telemetry: number of code changes applied so far.
    transitions: u64,
}

impl PrecisionController {
    pub fn new(num_layers: usize, cfg: PrecisionConfig) -> PrecisionController {
        let tau_low = cfg.tau_low;
        let tau_high = cfg.tau_high;
        PrecisionController {
            vars: (0..num_layers).map(|_| Ema::new(cfg.beta)).collect(),
            codes: vec![cfg.default_code; num_layers],
            promoted: vec![0; num_layers],
            cfg,
            tau_low,
            tau_high,
            calibrated: false,
            transitions: 0,
        }
    }

    pub fn num_layers(&self) -> usize {
        self.vars.len()
    }

    /// Feed one step's per-layer gradient variance (from the fused
    /// grad_stats kernel). Called every step; cheap (L EMA updates).
    pub fn observe(&mut self, grad_var: &[f32]) {
        assert_eq!(grad_var.len(), self.vars.len(), "grad_var arity");
        for (ema, &v) in self.vars.iter_mut().zip(grad_var) {
            // Overflowed/NaN steps carry no variance information.
            if v.is_finite() {
                ema.update(v as f64);
            }
        }
    }

    /// §3.2 precision promotion: pin layer `l` to FP32 for the next
    /// [`PROMOTION_WINDOWS`] control windows.
    pub fn promote(&mut self, l: usize) {
        self.promoted[l] = PROMOTION_WINDOWS;
        if self.codes[l] != FP32 {
            self.codes[l] = FP32;
            self.transitions += 1;
        }
    }

    /// Recompute per-layer codes; call on the `T_ctrl` cadence.
    /// Returns true if any code changed.
    pub fn control_window(&mut self) -> bool {
        if self.cfg.auto_threshold && !self.calibrated && self.ready() {
            self.calibrate();
        }
        let mut changed = false;
        for l in 0..self.codes.len() {
            if self.promoted[l] > 0 {
                self.promoted[l] -= 1;
                continue; // pinned to FP32 this window
            }
            let v = self.vars[l].get();
            if self.vars[l].steps() == 0 {
                continue; // no data yet — keep default
            }
            let target = self.classify(v, self.codes[l]);
            // Hysteresis rung limit: move at most one precision step.
            let next = step_toward(self.codes[l], target);
            if next != self.codes[l] {
                self.codes[l] = next;
                self.transitions += 1;
                changed = true;
            }
        }
        changed
    }

    /// The paper's threshold rule with a leave-side dead-band.
    fn classify(&self, v: f64, current: i32) -> i32 {
        let (lo, hi) = (self.tau_low, self.tau_high);
        match current {
            FP16 => {
                // Leaving FP16 requires clearing τ_low by the dead-band.
                if v >= hi {
                    FP32
                } else if v >= lo * (1.0 + HYSTERESIS) {
                    BF16
                } else {
                    FP16
                }
            }
            FP32 => {
                // Leaving FP32 requires dropping below τ_high by the band.
                if v < lo {
                    FP16
                } else if v < hi * (1.0 - HYSTERESIS) {
                    BF16
                } else {
                    FP32
                }
            }
            _ => {
                if v < lo {
                    FP16
                } else if v >= hi {
                    FP32
                } else {
                    BF16
                }
            }
        }
    }

    /// True once every layer has at least one variance sample.
    fn ready(&self) -> bool {
        self.vars.iter().all(|e| e.steps() > 0)
    }

    /// Percentile auto-calibration: τ_low = p25, τ_high = p90 of the
    /// observed cross-layer EMA variances (floored to keep ordering).
    fn calibrate(&mut self) {
        let mut vs: Vec<f64> = self.vars.iter().map(|e| e.get().max(1e-30)).collect();
        vs.sort_by(f64::total_cmp);
        let lo = crate::util::stats::percentile(&vs, 0.25);
        let hi = crate::util::stats::percentile(&vs, 0.90);
        if hi > lo {
            self.tau_low = lo;
            self.tau_high = hi;
        }
        self.calibrated = true;
    }

    pub fn codes(&self) -> &[i32] {
        &self.codes
    }

    /// Force a uniform code (used by the FP32 / static-AMP baselines and
    /// the ablation with dynamic precision off).
    pub fn pin_all(&mut self, code: i32) {
        for c in self.codes.iter_mut() {
            *c = code;
        }
    }

    pub fn thresholds(&self) -> (f64, f64) {
        (self.tau_low, self.tau_high)
    }

    pub fn variances(&self) -> Vec<f64> {
        self.vars.iter().map(|e| e.get()).collect()
    }

    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    /// Serialize the full controller state (codes, variance EMAs,
    /// promotion pins, calibrated thresholds) for checkpointing.
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        let mut vals = Vec::with_capacity(self.vars.len());
        let mut steps = Vec::with_capacity(self.vars.len());
        for e in &self.vars {
            let (v, s) = e.raw();
            vals.push(v);
            steps.push(s as f64);
        }
        vec![
            (key("codes"), self.codes.iter().map(|&c| c as f64).collect()),
            (key("var_values"), vals),
            (key("var_steps"), steps),
            (key("promoted"), self.promoted.iter().map(|&p| p as f64).collect()),
            (
                key("meta"),
                vec![
                    self.tau_low,
                    self.tau_high,
                    if self.calibrated { 1.0 } else { 0.0 },
                    self.transitions as f64,
                ],
            ),
        ]
    }

    /// Restore state written by [`Self::export_state`] (or the legacy
    /// `precision/…` keys of pre-policy checkpoints).
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        let n = self.vars.len();
        let codes = ckpt_lookup(kv, &[&key("codes"), "precision/codes"])?;
        let vals = ckpt_lookup(kv, &[&key("var_values"), "precision/var_values"])?;
        let steps = ckpt_lookup(kv, &[&key("var_steps"), "precision/var_steps"])?;
        let promoted = ckpt_lookup(kv, &[&key("promoted"), "precision/promoted"])?;
        let meta = ckpt_lookup(kv, &[&key("meta"), "precision/meta"])?;
        anyhow::ensure!(
            codes.len() == n && vals.len() == n && steps.len() == n && promoted.len() == n,
            "precision state arity mismatch ({} layers)",
            n
        );
        anyhow::ensure!(meta.len() == 4, "precision meta arity");
        for (i, &c) in codes.iter().enumerate() {
            let c = c as i32;
            anyhow::ensure!(
                [FP16, BF16, FP32].contains(&c),
                "invalid precision code {c} in checkpoint"
            );
            self.codes[i] = c;
        }
        for (ema, (&v, &s)) in self.vars.iter_mut().zip(vals.iter().zip(steps.iter())) {
            ema.set_raw(v, s as u64);
        }
        for (p, &v) in self.promoted.iter_mut().zip(promoted.iter()) {
            *p = v as u32;
        }
        self.tau_low = meta[0];
        self.tau_high = meta[1];
        self.calibrated = meta[2] > 0.5;
        self.transitions = meta[3] as u64;
        Ok(())
    }
}

const NAME: &str = "precision.adaptive";

fn key(field: &str) -> String {
    format!("policy/{NAME}/{field}")
}

impl PrecisionPolicy for PrecisionController {
    fn name(&self) -> &'static str {
        NAME
    }

    fn observe(&mut self, grad_var: &[f32]) {
        PrecisionController::observe(self, grad_var)
    }

    fn control_window(&mut self) -> bool {
        PrecisionController::control_window(self)
    }

    fn promote(&mut self, l: usize) -> bool {
        PrecisionController::promote(self, l);
        true
    }

    fn adaptive(&self) -> bool {
        true
    }

    fn codes(&self) -> &[i32] {
        PrecisionController::codes(self)
    }

    fn num_layers(&self) -> usize {
        PrecisionController::num_layers(self)
    }

    fn transitions(&self) -> u64 {
        PrecisionController::transitions(self)
    }

    fn variances(&self) -> Vec<f64> {
        PrecisionController::variances(self)
    }

    fn thresholds(&self) -> Option<(f64, f64)> {
        Some(PrecisionController::thresholds(self))
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        PrecisionController::export_state(self)
    }

    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        PrecisionController::import_state(self, kv)
    }
}

/// Constant precision: the FP32 baseline, static AMP, and the
/// precision-off ablation. Observations are ignored; promotions are
/// refused (the plane reports none, matching the pre-policy
/// controller, whose promotion path was gated on dynamic precision).
pub struct PinnedPrecision {
    codes: Vec<i32>,
}

impl PinnedPrecision {
    pub fn new(num_layers: usize, code: i32) -> PinnedPrecision {
        assert!([FP16, BF16, FP32].contains(&code), "invalid pin code {code}");
        PinnedPrecision { codes: vec![code; num_layers] }
    }
}

impl PrecisionPolicy for PinnedPrecision {
    fn name(&self) -> &'static str {
        "precision.pinned"
    }

    fn observe(&mut self, _grad_var: &[f32]) {}

    fn control_window(&mut self) -> bool {
        false
    }

    fn promote(&mut self, _l: usize) -> bool {
        false
    }

    fn adaptive(&self) -> bool {
        false
    }

    fn codes(&self) -> &[i32] {
        &self.codes
    }

    fn num_layers(&self) -> usize {
        self.codes.len()
    }

    fn transitions(&self) -> u64 {
        0
    }

    fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        vec![(
            "policy/precision.pinned/codes".to_string(),
            self.codes.iter().map(|&c| c as f64).collect(),
        )]
    }

    /// Pins are constitutive — set by the method spec, not the saved
    /// run — so imports only validate geometry when state is present
    /// (legacy checkpoints from pinned runs carried the full adaptive
    /// state; its values are irrelevant to a pinned policy).
    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        if let Some(codes) =
            ckpt_lookup_opt(kv, &["policy/precision.pinned/codes", "precision/codes"])
        {
            anyhow::ensure!(
                codes.len() == self.codes.len(),
                "pinned precision arity mismatch ({} layers)",
                self.codes.len()
            );
        }
        Ok(())
    }
}

/// Move `from` one rung toward `target` on the FP16 < BF16 < FP32 ladder.
/// Codes outside the ladder (impossible by construction — both come
/// from the policy's own code table) step nowhere.
fn step_toward(from: i32, target: i32) -> i32 {
    let (Some(f), Some(t)) = (rung(from), rung(target)) else {
        return from;
    };
    let next = if t > f { f + 1 } else if t < f { f - 1 } else { f };
    [FP16, BF16, FP32][next]
}

fn rung(code: i32) -> Option<usize> {
    match code {
        FP16 => Some(0),
        BF16 => Some(1),
        FP32 => Some(2),
        _ => None,
    }
}

/// Micikevicius-style dynamic loss scaling for the FP16 leg: halve on
/// overflow, double after `growth_interval` consecutive clean steps.
#[derive(Debug, Clone)]
pub struct LossScaler {
    scale: f32,
    growth_interval: u64,
    clean_steps: u64,
    overflows: u64,
    min_scale: f32,
    max_scale: f32,
}

impl LossScaler {
    pub fn new(init: f32, growth_interval: u64) -> LossScaler {
        LossScaler {
            scale: init,
            growth_interval: growth_interval.max(1),
            clean_steps: 0,
            overflows: 0,
            min_scale: 1.0,
            max_scale: 65536.0,
        }
    }

    /// Fixed scale of 1 — used when no FP16 layer exists (pure FP32 run).
    pub fn disabled() -> LossScaler {
        LossScaler {
            scale: 1.0,
            growth_interval: u64::MAX,
            clean_steps: 0,
            overflows: 0,
            min_scale: 1.0,
            max_scale: 1.0,
        }
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }

    pub fn overflows(&self) -> u64 {
        self.overflows
    }

    /// Serialize (scale, clean-step streak, overflow count).
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        vec![(
            "policy/scaler/state".into(),
            vec![self.scale as f64, self.clean_steps as f64, self.overflows as f64],
        )]
    }

    /// Restore state written by [`Self::export_state`] (or the legacy
    /// `scaler/state` key). The restored scale is clamped into the
    /// scaler's [min, max] band.
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        let v = ckpt_lookup(kv, &["policy/scaler/state", "scaler/state"])?;
        anyhow::ensure!(v.len() == 3, "scaler state arity");
        self.scale = (v[0] as f32).clamp(self.min_scale, self.max_scale);
        self.clean_steps = v[1] as u64;
        self.overflows = v[2] as u64;
        Ok(())
    }

    /// Record one step's overflow flag. Returns true when the step must
    /// be treated as skipped (the train graph already zeroes the update
    /// on overflow; this is for telemetry/control).
    pub fn update(&mut self, overflow: bool) -> bool {
        if overflow {
            self.overflows += 1;
            self.clean_steps = 0;
            self.scale = (self.scale * 0.5).max(self.min_scale);
            true
        } else {
            self.clean_steps += 1;
            if self.clean_steps >= self.growth_interval {
                self.clean_steps = 0;
                self.scale = (self.scale * 2.0).min(self.max_scale);
            }
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PrecisionConfig {
        PrecisionConfig {
            beta: 0.5,
            tau_low: 1e-4,
            tau_high: 1e-2,
            auto_threshold: false,
            default_code: BF16,
        }
    }

    #[test]
    fn starts_at_default_bf16() {
        let pc = PrecisionController::new(3, cfg());
        assert_eq!(pc.codes(), &[BF16, BF16, BF16]);
    }

    #[test]
    fn low_variance_descends_to_fp16() {
        let mut pc = PrecisionController::new(1, cfg());
        for _ in 0..10 {
            pc.observe(&[1e-7]);
            pc.control_window();
        }
        assert_eq!(pc.codes(), &[FP16]);
    }

    #[test]
    fn high_variance_ascends_to_fp32() {
        let mut pc = PrecisionController::new(1, cfg());
        for _ in 0..10 {
            pc.observe(&[1.0]);
            pc.control_window();
        }
        assert_eq!(pc.codes(), &[FP32]);
    }

    #[test]
    fn one_rung_per_window() {
        let mut pc = PrecisionController::new(1, cfg());
        // Drive straight to FP16 territory: first window only reaches...
        pc.observe(&[1e-8]);
        pc.control_window();
        assert_eq!(pc.codes(), &[FP16], "BF16→FP16 is one rung");
        // ...now jump to FP32 territory: must pass through BF16.
        pc.observe(&[10.0]);
        pc.observe(&[10.0]);
        pc.control_window();
        assert_eq!(pc.codes(), &[BF16]);
        pc.control_window();
        assert_eq!(pc.codes(), &[FP32]);
    }

    #[test]
    fn hysteresis_blocks_boundary_chatter() {
        let mut pc = PrecisionController::new(1, cfg());
        // Sit just above τ_low: from BF16 the enter-FP16 rule needs
        // v < τ_low, so we stay BF16 …
        for _ in 0..5 {
            pc.observe(&[1.1e-4]);
            pc.control_window();
        }
        assert_eq!(pc.codes(), &[BF16]);
        let t0 = pc.transitions();
        // … and oscillating ±5% around τ_low may settle into FP16 once
        // (enter rule is the paper's exact threshold) but must not
        // chatter back and forth: at most one transition total.
        for i in 0..20 {
            pc.observe(&[if i % 2 == 0 { 0.95e-4 } else { 1.05e-4 }]);
            pc.control_window();
        }
        assert!(
            pc.transitions() <= t0 + 1,
            "boundary chatter: {} transitions",
            pc.transitions() - t0
        );
    }

    #[test]
    fn promotion_pins_fp32_then_releases() {
        let mut pc = PrecisionController::new(2, cfg());
        for _ in 0..6 {
            pc.observe(&[1e-8, 1e-8]); // both want FP16
            pc.control_window();
        }
        assert_eq!(pc.codes(), &[FP16, FP16]);
        pc.promote(1);
        assert_eq!(pc.codes(), &[FP16, FP32]);
        // Pinned for PROMOTION_WINDOWS windows even under tiny variance.
        pc.observe(&[1e-8, 1e-8]);
        pc.control_window();
        assert_eq!(pc.codes()[1], FP32);
        pc.control_window();
        // After the pin expires it may descend again (one rung/window).
        pc.control_window();
        assert_eq!(pc.codes()[1], BF16);
        pc.control_window();
        assert_eq!(pc.codes()[1], FP16);
    }

    #[test]
    fn auto_threshold_calibrates_from_distribution() {
        let mut c = cfg();
        c.auto_threshold = true;
        // Absurd initial thresholds that would send everything to FP16.
        c.tau_low = 1e3;
        c.tau_high = 1e6;
        let mut pc = PrecisionController::new(4, c);
        // Layers with spread-out variances.
        for _ in 0..8 {
            pc.observe(&[1e-6, 1e-5, 1e-4, 1e-2]);
            pc.control_window();
        }
        let (lo, hi) = pc.thresholds();
        assert!(lo < hi && hi < 1e3, "calibrated: lo={lo} hi={hi}");
        // The top-variance layer must not be FP16 after calibration.
        assert_ne!(pc.codes()[3], FP16);
    }

    #[test]
    fn nan_variance_ignored() {
        let mut pc = PrecisionController::new(1, cfg());
        pc.observe(&[f32::NAN]);
        pc.control_window();
        assert_eq!(pc.codes(), &[BF16], "NaN carries no signal");
    }

    #[test]
    fn pin_all_overrides() {
        let mut pc = PrecisionController::new(3, cfg());
        pc.pin_all(FP32);
        assert_eq!(pc.codes(), &[FP32, FP32, FP32]);
    }

    #[test]
    fn pinned_policy_never_moves() {
        let mut pp = PinnedPrecision::new(3, BF16);
        pp.observe(&[1.0, 1.0, 1.0]);
        assert!(!PrecisionPolicy::control_window(&mut pp));
        assert!(!PrecisionPolicy::promote(&mut pp, 1));
        assert_eq!(PrecisionPolicy::codes(&pp), &[BF16, BF16, BF16]);
        assert!(!pp.adaptive());
        assert_eq!(PrecisionPolicy::transitions(&pp), 0);
    }

    #[test]
    fn pinned_import_validates_arity_only() {
        let mut pp = PinnedPrecision::new(2, FP32);
        // Legacy adaptive state from a 2-layer run: accepted, ignored.
        let kv = vec![("precision/codes".to_string(), vec![0.0, 1.0])];
        pp.import_state(&kv).unwrap();
        assert_eq!(PrecisionPolicy::codes(&pp), &[FP32, FP32]);
        // Wrong geometry is rejected loudly.
        let bad = vec![("precision/codes".to_string(), vec![0.0, 1.0, 2.0])];
        assert!(pp.import_state(&bad).is_err());
        // No state at all is fine (pins are constitutive).
        pp.import_state(&[]).unwrap();
    }

    #[test]
    fn adaptive_state_roundtrips_with_namespaced_keys() {
        let mut pc = PrecisionController::new(2, cfg());
        for _ in 0..4 {
            pc.observe(&[1e-7, 1.0]);
            pc.control_window();
        }
        let saved = PrecisionController::export_state(&pc);
        assert!(saved.iter().all(|(k, _)| k.starts_with("policy/precision.adaptive/")));
        let mut fresh = PrecisionController::new(2, cfg());
        fresh.import_state(&saved).unwrap();
        assert_eq!(fresh.codes(), pc.codes());
        assert_eq!(fresh.transitions(), pc.transitions());
        // Legacy keys import identically.
        let legacy: Vec<(String, Vec<f64>)> = saved
            .iter()
            .map(|(k, v)| {
                (k.replace("policy/precision.adaptive/", "precision/"), v.clone())
            })
            .collect();
        let mut old = PrecisionController::new(2, cfg());
        old.import_state(&legacy).unwrap();
        assert_eq!(old.codes(), pc.codes());
        assert_eq!(old.variances(), pc.variances());
    }

    #[test]
    fn loss_scaler_halves_and_grows() {
        let mut ls = LossScaler::new(1024.0, 4);
        assert!(ls.update(true));
        assert_eq!(ls.scale(), 512.0);
        for _ in 0..4 {
            assert!(!ls.update(false));
        }
        assert_eq!(ls.scale(), 1024.0);
        assert_eq!(ls.overflows(), 1);
    }

    #[test]
    fn loss_scaler_clamps() {
        let mut ls = LossScaler::new(2.0, 1);
        ls.update(true);
        ls.update(true);
        ls.update(true);
        assert_eq!(ls.scale(), 1.0, "floor at 1");
        let mut hi = LossScaler::new(65536.0, 1);
        hi.update(false);
        assert_eq!(hi.scale(), 65536.0, "cap holds");
    }

    #[test]
    fn disabled_scaler_is_inert() {
        let mut ls = LossScaler::disabled();
        ls.update(false);
        ls.update(true);
        assert_eq!(ls.scale(), 1.0);
    }
}
