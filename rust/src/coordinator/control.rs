//! §3.4 Unified Control Loop — the closed loop that couples the three
//! controllers on a `T_ctrl` cadence:
//!
//! 1. collect per-layer gradient variance (every step, cheap EMA) and
//!    curvature (every `T_curv`, via the AOT curv graph);
//! 2. adjust precision allocations p_l(t);
//! 3. adapt per-layer learning rates from curvature;
//! 4. update batch size B(t) from the VRAM signal.
//!
//! The interdependencies the paper calls out are all mediated here:
//! curvature promotes precision (`CurvatureScheduler::promotions` →
//! `PrecisionController::promote`), precision changes the memory model's
//! input (codes), memory drives batch size, and batch size feeds back
//! into gradient-variance statistics through the next steps' training.
//!
//! Method/ablation semantics (paper §4.1 baselines, Table 2 rows):
//! * `Fp32` — all layers pinned FP32, fixed batch, no curvature, scale 1.
//! * `AmpStatic` — all layers pinned BF16 (the paper's uniform policy;
//!   "BF16 is the default precision mode"), dynamic loss scale, fixed
//!   batch, no curvature.
//! * `TriAccel` — the full loop, with `Ablation` toggles selecting the
//!   Table-2 rows (+batch only, +precision only, full).

use crate::config::{Ablation, Config, Method};
use crate::manifest::{ModelEntry, BF16, FP16, FP32};

use super::batch::{BatchController, BatchMove};
use super::curvature::CurvatureScheduler;
use super::precision::{LossScaler, PrecisionController};
use super::{batch::BatchConfig, curvature::CurvatureConfig, precision::PrecisionConfig};

/// What one control window decided (telemetry / tests / traces).
#[derive(Debug, Clone)]
pub struct ControlDecision {
    pub step: u64,
    pub precision_changed: bool,
    pub promotions: Vec<usize>,
    pub batch_move: BatchMove,
    pub batch_size: usize,
    pub loss_scale: f32,
}

pub struct Controller {
    pub method: Method,
    pub ablation: Ablation,
    pub precision: PrecisionController,
    pub curvature: CurvatureScheduler,
    pub batch: BatchController,
    pub scaler: LossScaler,
    t_ctrl: u64,
    windows: u64,
}

impl Controller {
    pub fn new(cfg: &Config, entry: &ModelEntry) -> Controller {
        let ablation = match cfg.method {
            Method::TriAccel => cfg.ablation,
            _ => Ablation::none(),
        };
        let mut precision =
            PrecisionController::new(entry.num_layers, PrecisionConfig::from_cfg(cfg));
        match cfg.method {
            Method::Fp32 => precision.pin_all(FP32),
            Method::AmpStatic => precision.pin_all(BF16),
            Method::TriAccel if !ablation.dynamic_precision => precision.pin_all(BF16),
            _ => {}
        }
        let scaler = match cfg.method {
            Method::Fp32 => LossScaler::disabled(),
            _ => LossScaler::new(cfg.init_loss_scale, cfg.loss_scale_growth_interval),
        };
        Controller {
            method: cfg.method,
            ablation,
            precision,
            curvature: CurvatureScheduler::new(entry.num_layers, CurvatureConfig::from_cfg(cfg)),
            batch: BatchController::new(
                entry.train_buckets.clone(),
                cfg.batch_init,
                BatchConfig::from_cfg(cfg),
            ),
            scaler,
            t_ctrl: cfg.t_ctrl.max(1),
            windows: 0,
        }
    }

    /// Is the dynamic-precision path active (vs pinned)?
    fn precision_active(&self) -> bool {
        self.method == Method::TriAccel && self.ablation.dynamic_precision
    }

    /// Is the memory-elastic batch path active (vs the paper's static
    /// baselines, which keep B fixed and simply OOM)?
    pub fn batch_active(&self) -> bool {
        self.method == Method::TriAccel && self.ablation.dynamic_batch
    }

    fn curvature_active(&self) -> bool {
        self.method == Method::TriAccel && self.ablation.curvature
    }

    /// Per-step ingest: gradient variance + overflow flag from the train
    /// graph. O(L); runs every step.
    pub fn observe_step(&mut self, grad_var: &[f32], overflow: bool) {
        if self.precision_active() {
            self.precision.observe(grad_var);
        }
        // The scaler only matters while FP16 layers exist: BF16 shares
        // FP32's exponent range, so its overflow-free steps must not
        // grow the scale — a BF16-only run would otherwise ratchet the
        // scale to the cap while `loss_scale()` feeds 1.0 to the graph,
        // and a later FP16 demotion would inherit that absurd scale and
        // churn overflows until it halves back down. (The scaler itself
        // additionally clamps to [1, 65536].)
        if self.has_fp16_layers() {
            self.scaler.update(overflow);
        }
    }

    fn has_fp16_layers(&self) -> bool {
        self.precision.codes().contains(&FP16)
    }

    /// Should the trainer run a curvature probe at this step?
    pub fn curvature_due(&self, step: u64) -> bool {
        self.curvature_active() && self.curvature.due(step)
    }

    /// Ingest probe results; returns layers whose probe vectors must be
    /// reset (non-finite λ).
    pub fn observe_curvature(&mut self, lambdas: &[f32]) -> Vec<usize> {
        self.curvature.observe(lambdas)
    }

    /// Is `step` a control-window boundary (§3.4 cadence)?
    pub fn window_due(&self, step: u64) -> bool {
        step > 0 && step % self.t_ctrl == 0
    }

    /// One §3.4 control window. `mem_used`/`mem_max` from the memory
    /// monitor; `fits(b)` is the predictive OOM check for a candidate
    /// batch size *under the current precision codes*.
    pub fn control_window<F: FnMut(usize) -> bool>(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        fits: F,
    ) -> ControlDecision {
        self.windows += 1;

        // (2) precision from variance; (3) promotion from curvature.
        let mut promotions = Vec::new();
        let mut precision_changed = false;
        if self.precision_active() {
            precision_changed = self.precision.control_window();
            if self.curvature_active() {
                promotions = self.curvature.promotions();
                for &l in &promotions {
                    self.precision.promote(l);
                    precision_changed = true;
                }
            }
        }

        // (4) batch from memory.
        let batch_move = if self.batch_active() {
            self.batch.update(step, mem_used, mem_max, fits)
        } else {
            BatchMove::Hold
        };

        ControlDecision {
            step,
            precision_changed,
            promotions,
            batch_move,
            batch_size: self.batch.current(),
            loss_scale: self.scaler.scale(),
        }
    }

    /// The per-layer precision codes fed to the train executable.
    pub fn codes(&self) -> Vec<i32> {
        self.precision.codes().to_vec()
    }

    /// Per-layer LR scales; all-ones unless curvature is active+warm.
    pub fn lr_scales(&self) -> Vec<f32> {
        if self.curvature_active() {
            self.curvature.lr_scales()
        } else {
            vec![1.0; self.precision.num_layers()]
        }
    }

    /// The loss scale fed to the train executable. FP16 layers need a
    /// real scale; BF16/FP32-only runs use whatever the scaler holds
    /// (the graph divides it back out, so it is value-neutral).
    pub fn loss_scale(&self) -> f32 {
        if self.precision.codes().contains(&FP16) {
            self.scaler.scale()
        } else {
            1.0
        }
    }

    pub fn batch_size(&self) -> usize {
        self.batch.current()
    }

    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Serialize every sub-controller's state for checkpointing, so a
    /// resumed run continues exactly where the saved one stopped
    /// (precision codes + variance EMAs, curvature EMAs, loss-scaler
    /// value, batch-ladder position and cooldown anchor).
    pub fn export_state(&self) -> Vec<(String, Vec<f64>)> {
        let mut out = vec![("controller/windows".to_string(), vec![self.windows as f64])];
        out.extend(self.precision.export_state());
        out.extend(self.curvature.export_state());
        out.extend(self.batch.export_state());
        out.extend(self.scaler.export_state());
        out
    }

    /// Restore state written by [`Self::export_state`]. This
    /// controller's *method* stays authoritative: a pinned-precision
    /// run (FP32 / AMP-static / precision-off ablation) resuming a
    /// checkpoint saved under a different method must not adopt its
    /// adaptive codes or batch position — pins are re-applied after
    /// the import, exactly as [`Controller::new`] sets them.
    pub fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()> {
        if let Some((_, v)) = kv.iter().find(|(k, _)| k == "controller/windows") {
            anyhow::ensure!(v.len() == 1, "controller/windows arity");
            self.windows = v[0] as u64;
        }
        self.precision.import_state(kv)?;
        self.curvature.import_state(kv)?;
        if self.batch_active() {
            self.batch.import_state(kv)?;
        }
        self.scaler.import_state(kv)?;
        match self.method {
            Method::Fp32 => self.precision.pin_all(FP32),
            Method::AmpStatic => self.precision.pin_all(BF16),
            Method::TriAccel if !self.ablation.dynamic_precision => {
                self.precision.pin_all(BF16)
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::LayerSpec;
    use std::collections::BTreeMap;

    fn entry(num_layers: usize) -> ModelEntry {
        ModelEntry {
            key: "toy_c10".into(),
            model: "toy".into(),
            num_classes: 10,
            num_layers,
            param_count: 0,
            layers: (0..num_layers)
                .map(|i| LayerSpec {
                    name: format!("l{i}"),
                    kind: "conv".into(),
                    param_elems: 1000,
                    act_elems: 100,
                    flops: 10_000,
                })
                .collect(),
            params: vec![],
            nodes: vec![],
            state_shapes: vec![],
            train_buckets: vec![16, 32, 64, 96, 128],
            eval_buckets: vec![128],
            curv_batch: 32,
            artifacts: BTreeMap::new(),
        }
    }

    fn cfg(method: Method) -> Config {
        let mut c = Config::default();
        c.method = method;
        c.t_ctrl = 10;
        c.t_curv = 20;
        c.auto_threshold = false;
        c.tau_low = 1e-6;
        c.tau_high = 1e-3;
        c.batch_cooldown = 0;
        c
    }

    #[test]
    fn fp32_baseline_is_static() {
        let mut ctl = Controller::new(&cfg(Method::Fp32), &entry(3));
        assert_eq!(ctl.codes(), vec![FP32, FP32, FP32]);
        assert!(!ctl.curvature_due(200));
        ctl.observe_step(&[1e-9, 1e-9, 1e-9], false);
        let d = ctl.control_window(10, 0.1, 1.0, |_| true);
        assert!(!d.precision_changed);
        assert_eq!(d.batch_move, BatchMove::Hold);
        assert_eq!(ctl.loss_scale(), 1.0);
        assert_eq!(ctl.lr_scales(), vec![1.0; 3]);
    }

    #[test]
    fn amp_static_is_uniform_bf16_fixed_batch() {
        let mut ctl = Controller::new(&cfg(Method::AmpStatic), &entry(2));
        assert_eq!(ctl.codes(), vec![BF16, BF16]);
        for s in 1..=50 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.1, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![BF16, BF16], "static policy never moves");
        assert_eq!(ctl.batch_size(), 96);
    }

    #[test]
    fn tri_accel_adapts_precision_per_layer() {
        let mut ctl = Controller::new(&cfg(Method::TriAccel), &entry(2));
        for s in 1..=60 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16, FP32], "low-var down, high-var up");
    }

    #[test]
    fn tri_accel_grows_batch_when_memory_free() {
        let mut ctl = Controller::new(&cfg(Method::TriAccel), &entry(1));
        assert_eq!(ctl.batch_size(), 96);
        let d = ctl.control_window(10, 0.2, 1.0, |_| true);
        assert_eq!(d.batch_move, BatchMove::Grow);
        assert_eq!(ctl.batch_size(), 128);
    }

    #[test]
    fn ablation_flags_gate_components() {
        let mut c = cfg(Method::TriAccel);
        c.ablation.dynamic_precision = false;
        let mut ctl = Controller::new(&c, &entry(2));
        for s in 1..=60 {
            ctl.observe_step(&[1e-9, 1.0], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.2, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![BF16, BF16], "precision off → pinned");
        assert_eq!(ctl.batch_size(), 128, "batch still elastic");

        let mut c2 = cfg(Method::TriAccel);
        c2.ablation.dynamic_batch = false;
        let mut ctl2 = Controller::new(&c2, &entry(2));
        let d = ctl2.control_window(10, 0.1, 1.0, |_| true);
        assert_eq!(d.batch_move, BatchMove::Hold, "batch off → fixed");
    }

    #[test]
    fn curvature_promotion_flows_into_precision() {
        let mut c = cfg(Method::TriAccel);
        c.tau_curv = 5.0;
        c.curv_warmup = 1;
        let mut ctl = Controller::new(&c, &entry(2));
        // Drive both layers to FP16 first.
        for s in 1..=40 {
            ctl.observe_step(&[1e-9, 1e-9], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16, FP16]);
        assert!(ctl.curvature_due(40), "t_curv=20 divides 40");
        ctl.observe_curvature(&[0.1, 50.0]);
        let d = ctl.control_window(50, 0.8, 1.0, |_| true);
        assert_eq!(d.promotions, vec![1]);
        assert_eq!(ctl.codes()[1], FP32, "steep layer promoted");
        assert_eq!(ctl.codes()[0], FP16, "flat layer untouched");
    }

    #[test]
    fn loss_scale_only_applies_with_fp16_layers() {
        let ctl = Controller::new(&cfg(Method::AmpStatic), &entry(1));
        // BF16-only: graph receives neutral scale.
        assert_eq!(ctl.loss_scale(), 1.0);
        let mut c = cfg(Method::TriAccel);
        c.init_loss_scale = 512.0;
        let mut ctl2 = Controller::new(&c, &entry(1));
        for s in 1..=30 {
            ctl2.observe_step(&[1e-9], false);
            if ctl2.window_due(s) {
                ctl2.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl2.codes(), vec![FP16]);
        assert_eq!(ctl2.loss_scale(), 512.0);
        // Overflow halves it.
        ctl2.observe_step(&[1e-9], true);
        assert_eq!(ctl2.loss_scale(), 256.0);
    }

    #[test]
    fn bf16_only_run_never_moves_the_scale() {
        // The satellite bug: BF16 layers used to count as "half", so a
        // BF16-only run doubled the scale every growth interval while
        // feeding 1.0 to the graph — a later FP16 demotion then started
        // at an absurd scale. Scaler updates are now FP16-gated.
        let mut c = cfg(Method::AmpStatic);
        c.loss_scale_growth_interval = 2;
        c.init_loss_scale = 1024.0;
        let mut ctl = Controller::new(&c, &entry(2));
        for _ in 0..50 {
            ctl.observe_step(&[1e-9, 1e-9], false);
        }
        assert_eq!(ctl.scaler.scale(), 1024.0, "BF16-only must not grow the scale");
        assert_eq!(ctl.loss_scale(), 1.0);
    }

    #[test]
    fn fp16_layers_drive_the_scaler() {
        let mut c = cfg(Method::TriAccel);
        c.loss_scale_growth_interval = 3;
        c.init_loss_scale = 512.0;
        let mut ctl = Controller::new(&c, &entry(1));
        // Drive the single layer to FP16.
        for s in 1..=30 {
            ctl.observe_step(&[1e-9], false);
            if ctl.window_due(s) {
                ctl.control_window(s, 0.8, 1.0, |_| true);
            }
        }
        assert_eq!(ctl.codes(), vec![FP16]);
        let s0 = ctl.scaler.scale();
        for _ in 0..3 {
            ctl.observe_step(&[1e-9], false);
        }
        assert_eq!(ctl.scaler.scale(), s0 * 2.0, "clean FP16 steps grow the scale");
        assert!(ctl.scaler.scale() <= 65536.0);
    }

    #[test]
    fn controller_state_roundtrips() {
        let mut c = cfg(Method::TriAccel);
        c.tau_curv = 5.0;
        c.curv_warmup = 1;
        let mut ctl = Controller::new(&c, &entry(3));
        for s in 1..=45 {
            ctl.observe_step(&[1e-9, 1e-4, 1.0], s % 13 == 0);
            if s % 20 == 0 {
                ctl.observe_curvature(&[0.5, 2.0, 10.0]);
            }
            if ctl.window_due(s) {
                ctl.control_window(s, 0.85, 1.0, |_| true);
            }
        }
        let saved = ctl.export_state();
        let mut fresh = Controller::new(&c, &entry(3));
        fresh.import_state(&saved).unwrap();
        assert_eq!(fresh.codes(), ctl.codes());
        assert_eq!(fresh.batch_size(), ctl.batch_size());
        assert_eq!(fresh.scaler.scale(), ctl.scaler.scale());
        assert_eq!(fresh.lr_scales(), ctl.lr_scales());
        assert_eq!(fresh.windows(), ctl.windows());
        assert_eq!(fresh.precision.transitions(), ctl.precision.transitions());
        // Continued evolution must match step for step.
        for s in 46..=60 {
            ctl.observe_step(&[1e-9, 1e-4, 1.0], false);
            fresh.observe_step(&[1e-9, 1e-4, 1.0], false);
            if ctl.window_due(s) {
                let a = ctl.control_window(s, 0.5, 1.0, |_| true);
                let b = fresh.control_window(s, 0.5, 1.0, |_| true);
                assert_eq!(a.batch_size, b.batch_size);
                assert_eq!(a.loss_scale, b.loss_scale);
            }
            assert_eq!(ctl.codes(), fresh.codes());
        }
        // A mismatched geometry is rejected loudly.
        let mut wrong = Controller::new(&c, &entry(2));
        assert!(wrong.import_state(&saved).is_err());
    }

    #[test]
    fn window_cadence() {
        let ctl = Controller::new(&cfg(Method::TriAccel), &entry(1));
        assert!(!ctl.window_due(0));
        assert!(ctl.window_due(10));
        assert!(!ctl.window_due(15));
        assert!(ctl.window_due(20));
    }
}
