fn fill(v: &mut Vec<u8>, len: usize) {
    unsafe { v.set_len(len) };
}
