//! Deterministic RNG substrate (no `rand` crate in the offline build).
//!
//! xoshiro256++ with a splitmix64 seeder — the standard public-domain
//! construction. Everything in the data pipeline, augmentation, and
//! property tests derives from this, keyed by (seed, stream) so the
//! 3-seed protocol is bit-reproducible regardless of iteration order.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent stream: mixes a stream id into the seed. Used to give
    /// each (epoch, purpose) its own generator.
    pub fn stream(seed: u64, stream: u64) -> Self {
        Self::new(seed ^ stream.wrapping_mul(0xa076_1d64_78bd_642f))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
        assert_ne!(
            Rng::stream(1, 0).next_u64(),
            Rng::stream(1, 1).next_u64()
        );
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(42);
        let n = 20000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.next_normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
