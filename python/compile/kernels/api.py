"""Kernel dispatch surface for the L2 graphs.

The model code calls `api.qdq(...)` etc. and never touches pallas_call
directly. `set_backend("ref")` swaps every kernel for its pure-jnp oracle —
used (a) by pytest to diff the two paths through entire train graphs and
(b) to lower reference-numerics variants for A/B artifacts.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

from . import grad_stats as _grad_stats_mod
from . import mp_matmul as _mp_matmul_mod
from . import qdq as _qdq_mod
from . import ref
from . import sgd_update as _sgd_update_mod
from . import sr_qdq as _sr_qdq_mod

FP16, BF16, FP32 = ref.FP16, ref.BF16, ref.FP32

_state = threading.local()


def _backend() -> str:
    return getattr(_state, "backend", "pallas")


def set_backend(name: str) -> None:
    assert name in ("pallas", "ref"), name
    _state.backend = name


@contextlib.contextmanager
def backend(name: str):
    prev = _backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def qdq(x: jnp.ndarray, code) -> jnp.ndarray:
    code = jnp.asarray(code, jnp.int32)
    if _backend() == "ref":
        return ref.qdq_ref(x, code)
    return _qdq_mod.qdq(x, code)


def mp_matmul(x: jnp.ndarray, w: jnp.ndarray, code) -> jnp.ndarray:
    code = jnp.asarray(code, jnp.int32)
    if _backend() == "ref":
        return ref.mp_matmul_ref(x, w, code)
    return _mp_matmul_mod.mp_matmul(x, w, code)


def grad_stats(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    if _backend() == "ref":
        return ref.grad_stats_ref(jax.lax.stop_gradient(g))
    return _grad_stats_mod.grad_stats(g)


def sgd_update(p, m, g, lr_eff, wd, apply_mask):
    if _backend() == "ref":
        return ref.sgd_update_ref(p, m, g, lr_eff, wd, apply_mask)
    return _sgd_update_mod.sgd_update(p, m, g, lr_eff, wd, apply_mask)


def sr_qdq(x: jnp.ndarray, noise: jnp.ndarray, code) -> jnp.ndarray:
    code = jnp.asarray(code, jnp.int32)
    if _backend() == "ref":
        return ref.sr_qdq_ref(x, noise, code)
    return _sr_qdq_mod.sr_qdq(x, noise, code)
