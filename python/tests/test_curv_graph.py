"""Curvature probe: power-iteration convergence, block approximation, codes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import curv_graph, models
from compile.kernels import api


@pytest.fixture(scope="module")
def setup():
    m = models.build("tiny_cnn", num_classes=10)
    probe = jax.jit(curv_graph.make_curv_probe(m))
    return m, probe


def _batch(b=32, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, 32, 32, 3), dtype=np.float32))
    y = jnp.asarray(rng.integers(0, 10, b).astype(np.int32))
    return x, y


def _unit_probes(m, seed=1):
    rng = np.random.default_rng(seed)
    return tuple(
        jnp.asarray(rng.standard_normal(p.shape).astype(np.float32))
        for p in m.params
    )


def test_probe_shapes_and_finiteness(setup):
    m, probe = setup
    x, y = _batch()
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    u2, lam = probe(tuple(m.params), tuple(m.state), x, y, _unit_probes(m), codes)
    assert np.asarray(lam).shape == (m.num_layers,)
    assert np.all(np.isfinite(np.asarray(lam)))
    for v, spec in zip(u2, m.param_specs):
        assert v.shape == spec.shape
        assert np.all(np.isfinite(np.asarray(v)))


def test_next_probe_is_unit_per_layer(setup):
    m, probe = setup
    x, y = _batch(seed=2)
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    u2, _ = probe(tuple(m.params), tuple(m.state), x, y, _unit_probes(m, 3), codes)
    for li in range(m.num_layers):
        sq = sum(
            float(jnp.vdot(v, v))
            for v, s in zip(u2, m.param_specs)
            if s.layer_idx == li
        )
        np.testing.assert_allclose(np.sqrt(sq), 1.0, rtol=1e-4)


def test_power_iteration_converges(setup):
    """|λ| stabilizes under repeated probes on a fixed batch."""
    m, probe = setup
    x, y = _batch(seed=4)
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    u = _unit_probes(m, 5)
    lams = []
    for _ in range(12):
        u, lam = probe(tuple(m.params), tuple(m.state), x, y, u, codes)
        lams.append(np.asarray(lam))
    last, prev = np.abs(lams[-1]), np.abs(lams[-2])
    rel = np.abs(last - prev) / (np.abs(last) + 1e-8)
    assert np.median(rel) < 0.05, rel


def test_converged_lambda_dominates_rayleigh_of_random_probe(setup):
    """After convergence λ_max ≥ Rayleigh quotient of fresh random probes
    (the defining property of the top eigenvalue)."""
    m, probe = setup
    x, y = _batch(seed=6)
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    u = _unit_probes(m, 7)
    for _ in range(15):
        u, lam = probe(tuple(m.params), tuple(m.state), x, y, u, codes)
    lam = np.abs(np.asarray(lam))
    for seed in (8, 9):
        _, lam_r = probe(
            tuple(m.params), tuple(m.state), x, y, _unit_probes(m, seed), codes
        )
        lam_r = np.abs(np.asarray(lam_r))
        # Allow slack: cross-layer terms + single batch.
        assert np.mean(lam + 1e-6 >= lam_r * 0.5) > 0.7


def test_strict_block_mode_agrees_in_magnitude(setup):
    m, _ = setup
    x, y = _batch(seed=10)
    codes = jnp.full((m.num_layers,), api.FP32, jnp.int32)
    fast = jax.jit(curv_graph.make_curv_probe(m, strict_block=False))
    strict = jax.jit(curv_graph.make_curv_probe(m, strict_block=True))
    u = _unit_probes(m, 11)
    for _ in range(10):
        u_f, lam_f = fast(tuple(m.params), tuple(m.state), x, y, u, codes)
        u_s, lam_s = strict(tuple(m.params), tuple(m.state), x, y, u, codes)
        u = u_f
    lam_f, lam_s = np.asarray(lam_f), np.asarray(lam_s)
    # Same order of magnitude per layer (the control law is a 1/(1+αλ)
    # squash — factor-of-2 agreement is far below its sensitivity).
    ratio = (np.abs(lam_f) + 1e-8) / (np.abs(lam_s) + 1e-8)
    assert np.all(ratio > 0.2) and np.all(ratio < 5.0), ratio


def test_codes_affect_curvature(setup):
    m, probe = setup
    x, y = _batch(seed=12)
    u = _unit_probes(m, 13)
    for _ in range(5):
        u32, lam32 = probe(
            tuple(m.params), tuple(m.state), x, y, u,
            jnp.full((m.num_layers,), api.FP32, jnp.int32),
        )
        u16, lam16 = probe(
            tuple(m.params), tuple(m.state), x, y, u,
            jnp.full((m.num_layers,), api.FP16, jnp.int32),
        )
        u = u32
    assert not np.allclose(np.asarray(lam32), np.asarray(lam16))
