fn guarded(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Result<u32, ()> = Ok(1);
        assert_eq!(v.unwrap(), 1);
    }
}
