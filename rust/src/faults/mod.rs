//! Deterministic fault injection for the experiment scheduler.
//!
//! The scheduler's crash-safety claims (`docs/FAULTS.md`) are only as
//! good as the failures they were tested against. This module supplies
//! those failures on demand, *deterministically*: a [`FaultSpec`]
//! (parsed from the `--faults` CLI spec) plus a seed expands into a
//! [`FaultPlan`] that schedules
//!
//! * simulated **OOM storms** (a co-tenant burst crushes the live
//!   [`crate::memsim::VramSim`] budget and the attempt dies the way the
//!   kernel OOM-killer would kill it),
//! * **transient IO errors** on ledger and telemetry writes (injected
//!   through the [`ArtifactIo`] seam both writers go through),
//! * **job panics** (a [`PanicSink`] unwinds out of the trainer's
//!   telemetry emission — deep inside the real training stack), and
//! * **torn final ledger records** (a half-written line followed by a
//!   simulated process crash).
//!
//! Which jobs are hit is derived from the plan seed and the job-key
//! set alone — never from wall time, thread timing, or completion
//! order — so a plan is reproducible across runs, `--jobs` widths, and
//! resumes. Every fired fault is appended to `faults.jsonl` in the
//! grid directory; the plan reloads that log when it arms, which is
//! how one-shot faults stay consumed across a (simulated or real)
//! process restart instead of re-firing forever.
//!
//! The invariant that makes this more than chaos theater: a grid run
//! under any survivable plan produces report artifacts bit-identical
//! to the fault-free run (`tri-accel chaos` asserts it end-to-end).

// Enforced as an error by the docs CI job (`cargo doc` with
// `RUSTDOCFLAGS=-D warnings`); kept at `warn` here so tier-1
// `cargo build`/`cargo test` never hard-fails on a doc regression.
#![warn(missing_docs)]

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::checkpoint::fnv1a;
use crate::config::Config;
use crate::manifest::ModelEntry;
use crate::memsim::{self, MemoryMonitor, VramSim};
use crate::metrics::telemetry::TelemetrySink;
use crate::util::json::Json;

/// The accepted `--faults` grammar (shown by parse errors and
/// `docs/FAULTS.md`).
pub const FAULTS_GRAMMAR: &str =
    "seed:S,io:N,ledger_io:N,panic:N[:H],oom:N[:H],torn:N (comma-separated, any subset; \
     N = count, H = attempts hit, default 1)";

/// A parsed, validated fault plan specification. Pure data — expand it
/// into a live [`FaultPlan`] with [`FaultPlan::arm`] once the grid
/// directory and job-key set are known.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Plan seed: drives which jobs are targeted (and nothing else).
    pub seed: u64,
    /// Jobs whose telemetry stream gets one transient write error.
    pub io_jobs: usize,
    /// Ledger appends that fail transiently (nothing written), once each.
    pub ledger_io: usize,
    /// Jobs whose training panics (via the telemetry path), and how
    /// many attempts the panic hits before clearing.
    pub panic_jobs: usize,
    /// Attempts hit per panicking job (≥ 1).
    pub panic_hits: usize,
    /// Jobs killed by a simulated OOM storm, and how many attempts.
    pub oom_jobs: usize,
    /// Attempts hit per stormed job (≥ 1).
    pub oom_hits: usize,
    /// Torn ledger writes: a half-written record followed by a
    /// simulated process crash, once each.
    pub torn: usize,
}

impl FaultSpec {
    /// Parse a `--faults` spec. `""`, `none`, and `off` parse to the
    /// empty plan; anything else must match [`FAULTS_GRAMMAR`].
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let mut out = FaultSpec { panic_hits: 1, oom_hits: 1, ..FaultSpec::default() };
        let trimmed = spec.trim();
        if trimmed.is_empty() || trimmed == "none" || trimmed == "off" {
            return Ok(out);
        }
        for clause in trimmed.split(',') {
            let mut parts = clause.split(':');
            // detlint: allow(d6) — split always yields a first element.
            let name = parts.next().unwrap().trim();
            let rest: Vec<&str> = parts.collect();
            let field = |i: usize| -> Result<u64> {
                let v = rest.get(i).copied().with_context(|| {
                    format!("--faults clause `{clause}` is missing a value ({FAULTS_GRAMMAR})")
                })?;
                v.trim().parse().map_err(|_| {
                    anyhow::anyhow!("--faults clause `{clause}`: `{v}` is not a number")
                })
            };
            let count_only = |rest: &[&str]| -> Result<()> {
                anyhow::ensure!(
                    rest.len() == 1,
                    "--faults clause `{clause}` takes one value ({FAULTS_GRAMMAR})"
                );
                Ok(())
            };
            match name {
                "seed" => {
                    count_only(&rest)?;
                    out.seed = field(0)?;
                }
                "io" => {
                    count_only(&rest)?;
                    out.io_jobs = field(0)? as usize;
                }
                "ledger_io" => {
                    count_only(&rest)?;
                    out.ledger_io = field(0)? as usize;
                }
                "torn" => {
                    count_only(&rest)?;
                    out.torn = field(0)? as usize;
                }
                "panic" | "oom" => {
                    anyhow::ensure!(
                        (1..=2).contains(&rest.len()),
                        "--faults clause `{clause}` takes N or N:H ({FAULTS_GRAMMAR})"
                    );
                    let n = field(0)? as usize;
                    let hits = if rest.len() == 2 { field(1)? as usize } else { 1 };
                    anyhow::ensure!(hits >= 1, "--faults `{clause}`: H must be at least 1");
                    if name == "panic" {
                        out.panic_jobs = n;
                        out.panic_hits = hits;
                    } else {
                        out.oom_jobs = n;
                        out.oom_hits = hits;
                    }
                }
                other => anyhow::bail!(
                    "unknown --faults clause `{other}` — accepted grammar: {FAULTS_GRAMMAR}"
                ),
            }
        }
        let total = out.io_jobs + out.ledger_io + out.panic_jobs + out.oom_jobs + out.torn;
        anyhow::ensure!(total <= 10_000, "--faults plan is implausibly large ({total} faults)");
        Ok(out)
    }

    /// Does this spec inject anything at all?
    pub fn is_empty(&self) -> bool {
        self.io_jobs == 0
            && self.ledger_io == 0
            && self.panic_jobs == 0
            && self.oom_jobs == 0
            && self.torn == 0
    }

    /// Canonical one-line rendering (progress lines, fault log header).
    pub fn render(&self) -> String {
        format!(
            "seed:{},io:{},ledger_io:{},panic:{}:{},oom:{}:{},torn:{}",
            self.seed,
            self.io_jobs,
            self.ledger_io,
            self.panic_jobs,
            self.panic_hits,
            self.oom_jobs,
            self.oom_hits,
            self.torn
        )
    }
}

/// Per-job fault assignment (derived from the plan seed + job-key set).
#[derive(Debug, Clone, Default)]
struct JobFaults {
    /// Attempts 0..panic_hits panic.
    panic_hits: usize,
    /// Attempts 0..oom_hits die to a simulated OOM storm.
    oom_hits: usize,
    /// First telemetry append fails transiently.
    io: bool,
}

/// Mutable plan state, shared across scheduler workers.
#[derive(Debug, Default)]
struct PlanState {
    /// Ids of faults that already fired (persisted in `faults.jsonl`).
    consumed: BTreeSet<String>,
    /// A torn-write crash fired: every later ledger write in this
    /// process fails, simulating the process being dead.
    crashed: bool,
}

/// A live, armed fault plan for one grid directory. Shared by every
/// scheduler worker (and the [`FaultyIo`] seam) behind an `Arc`.
pub struct FaultPlan {
    spec: FaultSpec,
    targets: BTreeMap<String, JobFaults>,
    log_path: PathBuf,
    state: Mutex<PlanState>,
}

impl FaultPlan {
    /// Expand a spec against a grid: deterministically assign targeted
    /// jobs from the full job-key set (so targeting is identical on
    /// resume, when fewer jobs are pending) and reload the grid's
    /// fault log so already-fired one-shots stay consumed across
    /// restarts.
    pub fn arm(spec: &FaultSpec, grid_dir: &Path, job_keys: &[String]) -> Result<Arc<FaultPlan>> {
        // Rank job keys by seeded content hash (ties by key): a pure
        // function of (seed, key set) — independent of job order,
        // `--jobs` width, and completion timing.
        let mut ranked: Vec<(u64, &String)> = job_keys
            .iter()
            .map(|k| {
                let mut bytes = spec.seed.to_le_bytes().to_vec();
                bytes.extend_from_slice(k.as_bytes());
                (fnv1a(&bytes), k)
            })
            .collect();
        ranked.sort();
        let mut targets: BTreeMap<String, JobFaults> = BTreeMap::new();
        let mut cursor = ranked.iter().map(|(_, k)| (*k).clone());
        for key in cursor.by_ref().take(spec.panic_jobs.min(job_keys.len())) {
            targets.entry(key).or_default().panic_hits = spec.panic_hits;
        }
        for key in cursor.by_ref().take(spec.oom_jobs) {
            targets.entry(key).or_default().oom_hits = spec.oom_hits;
        }
        for key in cursor.take(spec.io_jobs) {
            targets.entry(key).or_default().io = true;
        }
        let log_path = grid_dir.join("faults.jsonl");
        let mut consumed = BTreeSet::new();
        if log_path.exists() {
            let text = std::fs::read_to_string(&log_path)
                .with_context(|| format!("reading fault log {}", log_path.display()))?;
            for line in text.lines() {
                // Tolerate a torn tail in the log itself — an
                // unparseable line simply doesn't mark anything consumed.
                if let Ok(j) = Json::parse(line) {
                    if let Some(id) = j.get("id").and_then(Json::as_str) {
                        consumed.insert(id.to_string());
                    }
                }
            }
        }
        Ok(Arc::new(FaultPlan {
            spec: spec.clone(),
            targets,
            log_path,
            state: Mutex::new(PlanState { consumed, crashed: false }),
        }))
    }

    /// The spec this plan was armed from.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Path of the append-only fault log (`<grid-dir>/faults.jsonl`).
    pub fn log_path(&self) -> &Path {
        &self.log_path
    }

    /// Fire a fault once: marks `id` consumed and appends a log line.
    /// Returns false (and injects nothing) if the fault already fired
    /// — including in a previous process, via the reloaded log — or if
    /// the log line cannot be persisted (a fault whose consumption
    /// can't be recorded would re-fire forever on restart).
    pub fn fire(&self, id: &str, kind: &str, detail: &str) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.consumed.contains(id) {
            return false;
        }
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Str(id.to_string()));
        m.insert("kind".to_string(), Json::Str(kind.to_string()));
        m.insert("detail".to_string(), Json::Str(detail.to_string()));
        let line = format!("{}\n", Json::Obj(m).to_string_compact());
        let appended = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.log_path)
            .and_then(|mut f| f.write_all(line.as_bytes()));
        if appended.is_err() {
            return false;
        }
        st.consumed.insert(id.to_string());
        if kind == "torn" {
            st.crashed = true;
        }
        true
    }

    /// Has a torn-write crash fired in this process? While true, every
    /// ledger write errors — the process is "dead" as far as the grid
    /// ledger is concerned.
    pub fn crashed(&self) -> bool {
        self.state.lock().unwrap().crashed
    }

    fn due(&self, id: &str) -> Option<String> {
        let st = self.state.lock().unwrap();
        if st.consumed.contains(id) {
            None
        } else {
            Some(id.to_string())
        }
    }

    /// Pending panic fault for this (job, attempt), if any.
    pub fn panic_due(&self, key: &str, attempt: usize) -> Option<String> {
        let t = self.targets.get(key)?;
        if attempt >= t.panic_hits {
            return None;
        }
        self.due(&format!("panic:{key}:a{attempt}"))
    }

    /// Pending OOM-storm fault for this (job, attempt), if any.
    pub fn oom_due(&self, key: &str, attempt: usize) -> Option<String> {
        let t = self.targets.get(key)?;
        if attempt >= t.oom_hits {
            return None;
        }
        self.due(&format!("oom:{key}:a{attempt}"))
    }

    /// Pending transient IO fault for this job's event stream, if any.
    pub fn events_io_due(&self, key: &str) -> Option<String> {
        let t = self.targets.get(key)?;
        if !t.io {
            return None;
        }
        self.due(&format!("io:{key}"))
    }

    /// Pending transient ledger-append fault, if any.
    pub fn ledger_io_due(&self) -> Option<String> {
        (1..=self.spec.ledger_io).find_map(|i| self.due(&format!("ledger_io:{i}")))
    }

    /// Pending torn-write (simulated crash) fault, if any.
    pub fn torn_due(&self) -> Option<String> {
        (1..=self.spec.torn).find_map(|i| self.due(&format!("torn:{i}")))
    }
}

// ---------------------------------------------------------------------------
// The artifact-IO seam.
// ---------------------------------------------------------------------------

/// The write seam both artifact writers go through: the grid ledger
/// (`sched::ledger`) and the telemetry JSONL sink
/// (`metrics::telemetry`). The default implementation is [`RealIo`];
/// [`FaultyIo`] wraps it to inject the plan's IO faults. A trait —
/// rather than direct `std::fs` calls — is what makes transient disk
/// errors testable without actually breaking the filesystem.
pub trait ArtifactIo: Send + Sync {
    /// Create `path` as an empty file (truncating any previous
    /// content; parent directories are created).
    fn create(&self, path: &Path) -> std::io::Result<()>;
    /// Append `text` — always whole records — to `path`, creating it
    /// if absent.
    fn append(&self, path: &Path, text: &str) -> std::io::Result<()>;
    /// Atomically replace `path` with `text` (temp file + rename): a
    /// kill mid-call leaves either the old or the new content.
    fn write_atomic(&self, path: &Path, text: &str) -> std::io::Result<()>;
}

/// Plain `std::fs` implementation of [`ArtifactIo`].
pub struct RealIo;

fn ensure_parent(path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    Ok(())
}

impl ArtifactIo for RealIo {
    fn create(&self, path: &Path) -> std::io::Result<()> {
        ensure_parent(path)?;
        std::fs::File::create(path).map(|_| ())
    }

    fn append(&self, path: &Path, text: &str) -> std::io::Result<()> {
        ensure_parent(path)?;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(text.as_bytes())
    }

    fn write_atomic(&self, path: &Path, text: &str) -> std::io::Result<()> {
        ensure_parent(path)?;
        let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
        name.push(".tmp");
        let tmp = path.with_file_name(name);
        std::fs::write(&tmp, text.as_bytes())?;
        std::fs::rename(&tmp, path)
    }
}

/// [`ArtifactIo`] that injects the plan's IO faults in front of
/// [`RealIo`]: transient errors on targeted event streams and ledger
/// appends, and torn ledger writes followed by a simulated crash.
pub struct FaultyIo {
    plan: Arc<FaultPlan>,
    inner: RealIo,
}

/// Is `path` the grid ledger?
fn is_ledger(path: &Path) -> bool {
    path.file_name().and_then(|n| n.to_str()) == Some("ledger.json")
}

/// Job key of an event stream path (`events/<key>.jsonl`), if it is one.
fn events_key(path: &Path) -> Option<&str> {
    if path.extension().and_then(|e| e.to_str()) != Some("jsonl") {
        return None;
    }
    if path.parent()?.file_name()?.to_str()? != "events" {
        return None;
    }
    path.file_stem()?.to_str()
}

/// Longest prefix of `text` not exceeding half its length that ends on
/// a char boundary — the torn write's payload.
fn torn_prefix(text: &str) -> &str {
    let mut cut = text.len() / 2;
    while cut > 0 && !text.is_char_boundary(cut) {
        cut -= 1;
    }
    &text[..cut]
}

impl FaultyIo {
    /// Wrap the real filesystem with a plan's IO faults.
    pub fn new(plan: Arc<FaultPlan>) -> FaultyIo {
        FaultyIo { plan, inner: RealIo }
    }
}

impl ArtifactIo for FaultyIo {
    fn create(&self, path: &Path) -> std::io::Result<()> {
        self.inner.create(path)
    }

    fn append(&self, path: &Path, text: &str) -> std::io::Result<()> {
        if is_ledger(path) {
            if self.plan.crashed() {
                return Err(std::io::Error::other(
                    "injected crash: process is simulated dead, ledger write suppressed",
                ));
            }
            if let Some(id) = self.plan.torn_due() {
                if self.plan.fire(&id, "torn", &format!("torn append to {}", path.display())) {
                    // Half a record lands on disk, then the "process
                    // dies": exactly the state recovery must repair.
                    self.inner.append(path, torn_prefix(text))?;
                    return Err(std::io::Error::other(format!(
                        "injected torn ledger write ({id}) — simulated crash"
                    )));
                }
            }
            if let Some(id) = self.plan.ledger_io_due() {
                if self.plan.fire(&id, "ledger_io", &format!("append to {}", path.display())) {
                    return Err(std::io::Error::other(format!(
                        "injected transient ledger IO error ({id})"
                    )));
                }
            }
        } else if let Some(key) = events_key(path) {
            if let Some(id) = self.plan.events_io_due(key) {
                if self.plan.fire(&id, "io", &format!("append to {}", path.display())) {
                    return Err(std::io::Error::other(format!(
                        "injected transient telemetry IO error ({id})"
                    )));
                }
            }
        }
        self.inner.append(path, text)
    }

    fn write_atomic(&self, path: &Path, text: &str) -> std::io::Result<()> {
        if is_ledger(path) && self.plan.crashed() {
            return Err(std::io::Error::other(
                "injected crash: process is simulated dead, ledger write suppressed",
            ));
        }
        self.inner.write_atomic(path, text)
    }
}

// ---------------------------------------------------------------------------
// In-attempt fault carriers.
// ---------------------------------------------------------------------------

/// A telemetry sink that panics on the first `step` event after firing
/// its fault — so the unwind originates inside the trainer's step
/// loop, crossing the real train → harness → scheduler stack before
/// the supervisor's `catch_unwind` contains it.
pub struct PanicSink {
    inner: Box<dyn TelemetrySink>,
    plan: Arc<FaultPlan>,
    id: String,
}

impl PanicSink {
    /// Wrap `inner`; the panic fires at most once (plan-gated).
    pub fn new(inner: Box<dyn TelemetrySink>, plan: Arc<FaultPlan>, id: String) -> PanicSink {
        PanicSink { inner, plan, id }
    }
}

impl TelemetrySink for PanicSink {
    fn emit(&mut self, event: &Json) {
        if event.get("event").and_then(Json::as_str) == Some("step")
            && self.plan.fire(&self.id, "panic", "telemetry panic inside the trainer step loop")
        {
            // No locks are held here: SharedSink's mutex is only taken
            // inside the inner sink's emit, which we have not called.
            panic!("injected fault: {}", self.id);
        }
        self.inner.emit(event);
    }
}

/// Simulate an OOM storm against this job's [`VramSim`]: install the
/// storm trace ([`memsim::storm_trace`]), account one step at the
/// smallest possible batch in full precision, and report the breach
/// the OOM killer would kill the job for. Always returns the error the
/// supervisor records for the attempt — by construction not even
/// batch 1 fits a stormed budget.
pub fn simulated_oom_storm(entry: &ModelEntry, cfg: &Config) -> anyhow::Error {
    let budget = if cfg.mem_budget_gb > 0.0 { cfg.mem_budget_gb } else { 1.0 };
    let mut sim = VramSim::new(entry, budget, 0.0, cfg.seed);
    sim.set_trace(memsim::storm_trace());
    sim.set_step(0);
    let codes = vec![crate::manifest::FP32; entry.layers.len()];
    let used = sim.usage(1, &codes, false).total_gb;
    let max = sim.mem_max_gb();
    anyhow::anyhow!(
        "injected OOM storm: batch 1 needs {used:.4} GiB against a stormed budget of \
         {max:.4} GiB — attempt killed"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_round_trips() {
        let s = FaultSpec::parse("seed:7,io:2,ledger_io:1,panic:1:3,oom:2,torn:1").unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.io_jobs, 2);
        assert_eq!(s.ledger_io, 1);
        assert_eq!((s.panic_jobs, s.panic_hits), (1, 3));
        assert_eq!((s.oom_jobs, s.oom_hits), (2, 1));
        assert_eq!(s.torn, 1);
        assert!(!s.is_empty());
        assert_eq!(FaultSpec::parse(&s.render()).unwrap(), s, "render re-parses");
        for empty in ["", "none", "off", "  "] {
            assert!(FaultSpec::parse(empty).unwrap().is_empty(), "`{empty}`");
        }
    }

    #[test]
    fn spec_rejects_malformed_clauses_with_grammar() {
        for bad in ["wobble:1", "panic", "io:x", "panic:1:0", "seed:1:2", "io:1:2"] {
            let err = FaultSpec::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("--faults") || err.contains("H must be"),
                "`{bad}` → {err}"
            );
        }
        let err = FaultSpec::parse("frob:1").unwrap_err().to_string();
        assert!(err.contains("seed:S"), "grammar listed: {err}");
    }

    fn tmp_dir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("triaccel_faults_{name}_{}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("{i:02}_tiny_cnn_c10_fp32_s0")).collect()
    }

    #[test]
    fn targeting_is_seeded_and_deterministic() {
        let dir = tmp_dir("target");
        let spec = FaultSpec::parse("seed:3,panic:2,oom:1,io:1").unwrap();
        let a = FaultPlan::arm(&spec, &dir, &keys(8)).unwrap();
        let b = FaultPlan::arm(&spec, &dir, &keys(8)).unwrap();
        let hit = |p: &FaultPlan| -> Vec<String> {
            keys(8)
                .into_iter()
                .filter(|k| {
                    p.panic_due(k, 0).is_some()
                        || p.oom_due(k, 0).is_some()
                        || p.events_io_due(k).is_some()
                })
                .collect()
        };
        assert_eq!(hit(&a), hit(&b), "same seed, same targets");
        assert_eq!(hit(&a).len(), 4, "2 panic + 1 oom + 1 io, disjoint");
        let other = FaultSpec::parse("seed:4,panic:2,oom:1,io:1").unwrap();
        let c = FaultPlan::arm(&other, &dir, &keys(8)).unwrap();
        assert_ne!(hit(&a), hit(&c), "seed moves the targets");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fired_faults_stay_consumed_across_rearm() {
        let dir = tmp_dir("consume");
        let spec = FaultSpec::parse("seed:0,torn:1,ledger_io:1").unwrap();
        let plan = FaultPlan::arm(&spec, &dir, &keys(2)).unwrap();
        let id = plan.torn_due().unwrap();
        assert!(plan.fire(&id, "torn", "test"));
        assert!(!plan.fire(&id, "torn", "test"), "one-shot");
        assert!(plan.crashed(), "torn fault simulates a crash");
        assert!(plan.torn_due().is_none());
        // Re-arm (simulated restart): the log keeps it consumed, and
        // the crash flag resets with the new process.
        let again = FaultPlan::arm(&spec, &dir, &keys(2)).unwrap();
        assert!(again.torn_due().is_none(), "log persists consumption");
        assert!(!again.crashed());
        assert!(again.ledger_io_due().is_some(), "unfired faults stay armed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faulty_io_tears_then_crashes_ledger_writes() {
        let dir = tmp_dir("torn");
        let ledger = dir.join("ledger.json");
        let spec = FaultSpec::parse("torn:1").unwrap();
        let plan = FaultPlan::arm(&spec, &dir, &keys(1)).unwrap();
        let io = FaultyIo::new(plan.clone());
        io.append(&ledger, "{\"ok\":1}\n").unwrap_err();
        let text = std::fs::read_to_string(&ledger).unwrap();
        assert_eq!(text, torn_prefix("{\"ok\":1}\n"), "half the record landed");
        io.append(&ledger, "{\"ok\":2}\n").unwrap_err();
        io.write_atomic(&ledger, "x").unwrap_err();
        assert_eq!(std::fs::read_to_string(&ledger).unwrap(), text, "dead process writes nothing");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn attempt_hits_gate_panic_and_oom() {
        let dir = tmp_dir("hits");
        let spec = FaultSpec::parse("panic:1:2").unwrap();
        let plan = FaultPlan::arm(&spec, &dir, &keys(1)).unwrap();
        let key = &keys(1)[0];
        assert!(plan.panic_due(key, 0).is_some());
        assert!(plan.panic_due(key, 1).is_some());
        assert!(plan.panic_due(key, 2).is_none(), "third attempt is clean");
        assert!(plan.oom_due(key, 0).is_none(), "no oom targets in this plan");
        std::fs::remove_dir_all(&dir).ok();
    }
}
