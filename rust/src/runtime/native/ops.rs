//! Neural-net primitives for the native backend: SAME 3×3 convolution,
//! BatchNorm (train/eval), 2×2 max-pool, global average pool, dense
//! matmul, and softmax cross-entropy — each with its backward pass.
//!
//! Semantics are a port of `python/compile/models/common.py` +
//! `python/compile/train_graph.py` (validated against the JAX reference
//! graphs numerically): NHWC layout, f32 activations, fp32-style
//! accumulation, batch-stat BN with torch-style running updates.
//! Channel reductions (BN statistics, BN backward sums, CE loss mean)
//! accumulate in f64 for robustness; everything stored is f32.
//!
//! Two API tiers:
//! * `*_into` variants — the hot path: write into caller-provided
//!   (arena) buffers, allocate nothing, and route the heavy matmuls
//!   through the tiled [`super::gemm`] core (conv = im2col+GEMM,
//!   dense = GEMM). This is what `tiny_cnn.rs` drives.
//! * the original `Vec`-returning signatures — compat/test wrappers
//!   over the same kernels, using a thread-local scratch [`Exec`] so
//!   repeated calls (gradchecks, benches) stay warm.
//!
//! Loss-scale exactness: every backward op here is *linear* in the
//! incoming cotangent, so scaling the loss by 2^k scales every gradient
//! by exactly 2^k in binary floating point — the property the FP32
//! value-neutrality test pins down.

#![allow(clippy::too_many_arguments)]

use std::cell::RefCell;

use super::gemm;
use super::simd;
use super::Exec;
use crate::manifest::FP32;

pub const BN_MOMENTUM: f32 = 0.1;
pub const BN_EPS: f32 = 1e-5;

/// Channel-block width for the stack-resident f64 accumulators (BN
/// statistics, GAP sums): wide enough to cover every tiny_cnn layer in
/// one block, small enough to live in registers/L1. Blocking is
/// bit-compatible with the former full-width heap accumulators because
/// per-channel sums are independent and keep their row order.
const CBLK: usize = 64;

thread_local! {
    /// Warm scratch for the compat wrappers, so gradchecks and benches
    /// that call the `Vec`-returning API in a loop don't re-allocate
    /// im2col panels on every call.
    static COMPAT: RefCell<Exec> = RefCell::new(Exec::from_env());
}

fn with_exec<R>(f: impl FnOnce(&mut Exec) -> R) -> R {
    COMPAT.with(|e| f(&mut e.borrow_mut()))
}

// ------------------------------------------------------------------ conv

/// SAME-padded k×k stride-`s` convolution. `x` is NHWC `(n,h,w,cin)`
/// flat, `wt` is HWIO `(k,k,cin,cout)` flat; returns `(n,ho,wo,cout)`
/// with `ho = ceil(h/s)`. Executes as im2col + tiled GEMM.
pub fn conv_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * h * w * cin);
    debug_assert_eq!(wt.len(), k * k * cin * cout);
    with_exec(|ex| {
        let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
        let m = n * ho * wo;
        let kk = k * k * cin;
        let mut out = vec![0f32; m * cout];
        let mut cols = ex.arena.take(m * kk);
        gemm::im2col_qdq(&ex.pool, x, n, h, w, cin, k, stride, FP32, &mut cols);
        gemm::gemm(&ex.pool, &mut ex.arena, &cols, wt, &mut out, m, kk, cout, false);
        ex.arena.put(cols);
        out
    })
}

/// Backward of [`conv_fwd`]: returns `(dx, dw)` for cotangent `g` of
/// shape `(n,ho,wo,cout)`. `dw = x_colsᵀ·g` (ordered-reduction GEMM),
/// `dx = col2im(g·Wᵀ)`.
pub fn conv_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
    g: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
    debug_assert_eq!(g.len(), n * ho * wo * cout);
    with_exec(|ex| {
        let m = n * ho * wo;
        let kk = k * k * cin;
        let mut cols = ex.arena.take(m * kk);
        gemm::im2col_qdq(&ex.pool, x, n, h, w, cin, k, stride, FP32, &mut cols);
        let mut dw = vec![0f32; k * k * cin * cout];
        gemm::gemm_at_b(&ex.pool, &mut ex.arena, &cols, g, &mut dw, m, kk, cout);
        ex.arena.put(cols);
        let mut dcols = ex.arena.take(m * kk);
        gemm::gemm_a_bt(&ex.pool, &mut ex.arena, g, wt, &mut dcols, m, cout, kk, false);
        let mut dx = vec![0f32; x.len()];
        gemm::col2im(&ex.pool, &dcols, n, h, w, cin, k, stride, &mut dx);
        ex.arena.put(dcols);
        (dx, dw)
    })
}

/// SAME-padded 3×3 stride-1 convolution (compat wrapper over
/// [`conv_fwd`] — the tiny_cnn shape).
pub fn conv3x3_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    cout: usize,
) -> Vec<f32> {
    conv_fwd(x, n, h, w, cin, wt, cout, 3, 1)
}

/// Backward of [`conv3x3_fwd`] (compat wrapper over [`conv_bwd`]).
pub fn conv3x3_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    wt: &[f32],
    cout: usize,
    g: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    conv_bwd(x, n, h, w, cin, wt, cout, 3, 1, g)
}

// --------------------------------------------------------------- dwconv

/// SAME-padded depthwise k×k stride-`s` convolution: one k×k filter per
/// channel, no cross-channel mixing. `x` is NHWC `(n,h,w,c)` flat, `wt`
/// is `(k,k,1,c)` flat (tap-major: `wt[(ky*k+kx)*c + ci]`); writes
/// `(n,ho,wo,c)`. Direct accumulation in fixed ascending tap order —
/// too few MACs per output to be worth the im2col detour, and the fixed
/// order keeps the cross-thread bit-identity contract. One parallel
/// chunk per image.
pub fn dwconv_fwd_into(
    pool: &super::pool::Pool,
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    wt: &[f32],
    out: &mut [f32],
) {
    let pad = (k - 1) / 2;
    let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
    debug_assert_eq!(x.len(), n * h * w * c);
    debug_assert_eq!(wt.len(), k * k * c);
    debug_assert_eq!(out.len(), n * ho * wo * c);
    let parallel = out.len() * k * k >= 1 << 19;
    let tier = simd::active();
    pool.for_each_chunk(out, ho * wo * c, parallel, |bi, img| {
        for oy in 0..ho {
            for ox in 0..wo {
                let orow = &mut img[(oy * wo + ox) * c..(oy * wo + ox + 1) * c];
                orow.fill(0.0);
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xrow =
                            &x[((bi * h + iy as usize) * w + ix as usize) * c..][..c];
                        let wrow = &wt[(ky * k + kx) * c..(ky * k + kx + 1) * c];
                        simd::mul_acc(tier, orow, xrow, wrow);
                    }
                }
            }
        }
    });
}

/// Weight gradient of the depthwise conv: `dw[(ky,kx),ci] = Σ_pixels
/// x[iy,ix,ci]·g[oy,ox,ci]`. Runs serially on the caller in ascending
/// (image, pixel, tap) order — the tensor is tiny (k²·c) and a serial
/// ordered reduction is trivially thread-count invariant.
pub fn dwconv_dw_into(
    x: &[f32],
    g: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    dw: &mut [f32],
) {
    let pad = (k - 1) / 2;
    let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
    debug_assert_eq!(g.len(), n * ho * wo * c);
    debug_assert_eq!(dw.len(), k * k * c);
    dw.fill(0.0);
    let tier = simd::active();
    for bi in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let grow = &g[((bi * ho + oy) * wo + ox) * c..][..c];
                for ky in 0..k {
                    let iy = (oy * stride + ky) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * stride + kx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let xrow =
                            &x[((bi * h + iy as usize) * w + ix as usize) * c..][..c];
                        let drow = &mut dw[(ky * k + kx) * c..(ky * k + kx + 1) * c];
                        simd::mul_acc(tier, drow, xrow, grow);
                    }
                }
            }
        }
    }
}

/// Input gradient of the depthwise conv, gather form (the adjoint of
/// [`dwconv_fwd_into`]): each `dx` element sums its contributing output
/// positions in fixed tap order. One parallel chunk per image, no
/// scatter races.
pub fn dwconv_dx_into(
    pool: &super::pool::Pool,
    g: &[f32],
    wt: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    dx: &mut [f32],
) {
    let pad = (k - 1) / 2;
    let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
    debug_assert_eq!(g.len(), n * ho * wo * c);
    debug_assert_eq!(dx.len(), n * h * w * c);
    let parallel = dx.len() * k * k >= 1 << 19;
    let tier = simd::active();
    pool.for_each_chunk(dx, h * w * c, parallel, |bi, img| {
        for iy in 0..h {
            for ix in 0..w {
                let drow = &mut img[(iy * w + ix) * c..(iy * w + ix + 1) * c];
                drow.fill(0.0);
                for ky in 0..k {
                    let t = iy + pad;
                    if t < ky || (t - ky) % stride != 0 {
                        continue;
                    }
                    let oy = (t - ky) / stride;
                    if oy >= ho {
                        continue;
                    }
                    for kx in 0..k {
                        let u = ix + pad;
                        if u < kx || (u - kx) % stride != 0 {
                            continue;
                        }
                        let ox = (u - kx) / stride;
                        if ox >= wo {
                            continue;
                        }
                        let grow = &g[((bi * ho + oy) * wo + ox) * c..][..c];
                        let wrow = &wt[(ky * k + kx) * c..(ky * k + kx + 1) * c];
                        simd::mul_acc(tier, drow, grow, wrow);
                    }
                }
            }
        }
    });
}

/// Depthwise conv (compat wrapper over [`dwconv_fwd_into`]).
pub fn dwconv_fwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    wt: &[f32],
) -> Vec<f32> {
    with_exec(|ex| {
        let (ho, wo) = (gemm::conv_out_dim(h, stride), gemm::conv_out_dim(w, stride));
        let mut out = vec![0f32; n * ho * wo * c];
        dwconv_fwd_into(&ex.pool, x, n, h, w, c, k, stride, wt, &mut out);
        out
    })
}

/// Backward of [`dwconv_fwd`] (compat wrapper): returns `(dx, dw)`.
pub fn dwconv_bwd(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    wt: &[f32],
    g: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    with_exec(|ex| {
        let mut dw = vec![0f32; k * k * c];
        dwconv_dw_into(x, g, n, h, w, c, k, stride, &mut dw);
        let mut dx = vec![0f32; x.len()];
        dwconv_dx_into(&ex.pool, g, wt, n, h, w, c, k, stride, &mut dx);
        (dx, dw)
    })
}

// -------------------------------------------------------------------- bn

/// Per-channel statistics cached by the BN forward for the backward.
pub struct BnCache {
    pub mean: Vec<f32>,
    pub inv: Vec<f32>, // 1/sqrt(var + eps)
}

/// Allocation-free BatchNorm forward. `x` is `(rows, c)` flat with
/// `rows = n*h*w`; `mean`/`inv` receive the statistics the backward
/// needs (in eval mode: the running stats). Train mode writes
/// torch-style updated running stats into `new_rm`/`new_rv`; eval
/// copies them through unchanged.
pub fn bn_fwd_into(
    x: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    rm: &[f32],
    rv: &[f32],
    train: bool,
    out: &mut [f32],
    new_rm: &mut [f32],
    new_rv: &mut [f32],
    mean: &mut [f32],
    inv: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * c);
    debug_assert_eq!(out.len(), rows * c);
    if train {
        for c0 in (0..c).step_by(CBLK) {
            let cb = (c - c0).min(CBLK);
            let mut sum = [0f64; CBLK];
            for r in 0..rows {
                let row = &x[r * c + c0..r * c + c0 + cb];
                for (s, &v) in sum[..cb].iter_mut().zip(row.iter()) {
                    *s += v as f64;
                }
            }
            for i in 0..cb {
                mean[c0 + i] = (sum[i] / rows as f64) as f32;
            }
            let mut sq = [0f64; CBLK];
            for r in 0..rows {
                let row = &x[r * c + c0..r * c + c0 + cb];
                for (i, &v) in row.iter().enumerate() {
                    let d = (v - mean[c0 + i]) as f64;
                    sq[i] += d * d;
                }
            }
            for i in 0..cb {
                let var = (sq[i] / rows as f64) as f32;
                inv[c0 + i] = 1.0 / (var + BN_EPS).sqrt();
                new_rm[c0 + i] = (1.0 - BN_MOMENTUM) * rm[c0 + i] + BN_MOMENTUM * mean[c0 + i];
                new_rv[c0 + i] = (1.0 - BN_MOMENTUM) * rv[c0 + i] + BN_MOMENTUM * var;
            }
        }
    } else {
        mean.copy_from_slice(rm);
        for (iv, &v) in inv.iter_mut().zip(rv.iter()) {
            *iv = 1.0 / (v + BN_EPS).sqrt();
        }
        new_rm.copy_from_slice(rm);
        new_rv.copy_from_slice(rv);
    }
    for r in 0..rows {
        for ci in 0..c {
            out[r * c + ci] = (x[r * c + ci] - mean[ci]) * inv[ci] * gamma[ci] + beta[ci];
        }
    }
}

/// BatchNorm forward (compat wrapper over [`bn_fwd_into`]). In train
/// mode uses batch statistics and returns torch-style updated running
/// stats; in eval mode normalizes with `(rm, rv)` unchanged. Returns
/// `(out, new_rm, new_rv, cache)`.
pub fn bn_fwd(
    x: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    rm: &[f32],
    rv: &[f32],
    train: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, BnCache) {
    let mut out = vec![0f32; rows * c];
    let mut new_rm = vec![0f32; c];
    let mut new_rv = vec![0f32; c];
    let mut mean = vec![0f32; c];
    let mut inv = vec![0f32; c];
    bn_fwd_into(
        x,
        rows,
        c,
        gamma,
        beta,
        rm,
        rv,
        train,
        &mut out,
        &mut new_rm,
        &mut new_rv,
        &mut mean,
        &mut inv,
    );
    (out, new_rm, new_rv, BnCache { mean, inv })
}

/// Allocation-free BN train-mode backward (batch statistics participate
/// in the gradient). `mean`/`inv` are the forward's cached statistics.
pub fn bn_bwd_into(
    x: &[f32],
    g: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    mean: &[f32],
    inv: &[f32],
    dx: &mut [f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) {
    debug_assert_eq!(g.len(), rows * c);
    for c0 in (0..c).step_by(CBLK) {
        let cb = (c - c0).min(CBLK);
        let mut db = [0f64; CBLK];
        let mut dg = [0f64; CBLK];
        for r in 0..rows {
            for i in 0..cb {
                let ci = c0 + i;
                let gv = g[r * c + ci] as f64;
                let xhat = ((x[r * c + ci] - mean[ci]) * inv[ci]) as f64;
                db[i] += gv;
                dg[i] += gv * xhat;
            }
        }
        for i in 0..cb {
            dgamma[c0 + i] = dg[i] as f32;
            dbeta[c0 + i] = db[i] as f32;
        }
    }
    let nf = rows as f32;
    for r in 0..rows {
        for ci in 0..c {
            let xhat = (x[r * c + ci] - mean[ci]) * inv[ci];
            let coeff = gamma[ci] * inv[ci] / nf;
            dx[r * c + ci] =
                coeff * (nf * g[r * c + ci] - dbeta[ci] - xhat * dgamma[ci]);
        }
    }
}

// ---- sharded BN primitives (the data-parallel replica path) ----
//
// The replica executor (`super::replica`) computes BN over the *whole*
// batch while the batch lives in fixed canonical shards: each shard
// contributes per-channel sufficient statistics (Σx, Σx²) in its own
// row order, the orchestrator reduces the partials in ascending shard
// order, and every shard then normalizes against the shared global
// statistics. The same split applies to the backward's Σg / Σg·x̂
// sums. Partials accumulate in f64 (like the fused path) and reduce
// deterministically, so the result depends only on the canonical shard
// boundaries — never on how many replicas processed them.

/// Per-channel sufficient statistics of one shard: accumulates
/// `Σx` into `sum` and `Σx²` into `sq` (CBLK-blocked, row order).
/// Callers zero the accumulators; an empty shard is a no-op.
pub fn bn_partial_into(x: &[f32], rows: usize, c: usize, sum: &mut [f64], sq: &mut [f64]) {
    debug_assert_eq!(x.len(), rows * c);
    debug_assert_eq!(sum.len(), c);
    debug_assert_eq!(sq.len(), c);
    for c0 in (0..c).step_by(CBLK) {
        let cb = (c - c0).min(CBLK);
        let mut s = [0f64; CBLK];
        let mut q = [0f64; CBLK];
        for r in 0..rows {
            let row = &x[r * c + c0..r * c + c0 + cb];
            for (i, &v) in row.iter().enumerate() {
                let vd = v as f64;
                s[i] += vd;
                q[i] += vd * vd;
            }
        }
        for i in 0..cb {
            sum[c0 + i] += s[i];
            sq[c0 + i] += q[i];
        }
    }
}

/// Finalize globally-reduced BN sufficient statistics: `mean`, the
/// inverse stddev `inv`, and torch-style updated running stats.
/// `var = Σx²/rows − mean²` clamped at zero (one-pass form; the fused
/// single-engine path uses the two-pass form, so the replica path is
/// its own pinned numeric contract — see docs/DETERMINISM.md).
pub fn bn_finalize_stats(
    sum: &[f64],
    sq: &[f64],
    rows: usize,
    rm: &[f32],
    rv: &[f32],
    mean: &mut [f32],
    inv: &mut [f32],
    new_rm: &mut [f32],
    new_rv: &mut [f32],
) {
    let n = rows as f64;
    for ci in 0..sum.len() {
        let m = sum[ci] / n;
        let var = ((sq[ci] / n - m * m).max(0.0)) as f32;
        mean[ci] = m as f32;
        inv[ci] = 1.0 / (var + BN_EPS).sqrt();
        new_rm[ci] = (1.0 - BN_MOMENTUM) * rm[ci] + BN_MOMENTUM * mean[ci];
        new_rv[ci] = (1.0 - BN_MOMENTUM) * rv[ci] + BN_MOMENTUM * var;
    }
}

/// Normalize one shard against shared (global) statistics — the apply
/// half of the sharded BN forward, also usable for eval-mode stats.
pub fn bn_apply_into(
    x: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    inv: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * c);
    debug_assert_eq!(out.len(), rows * c);
    for r in 0..rows {
        for ci in 0..c {
            out[r * c + ci] = (x[r * c + ci] - mean[ci]) * inv[ci] * gamma[ci] + beta[ci];
        }
    }
}

/// One shard's partial BN backward sums: accumulates `Σg` into `db`
/// and `Σg·x̂` into `dg` (CBLK-blocked, row order).
pub fn bn_bwd_partial_into(
    x: &[f32],
    g: &[f32],
    rows: usize,
    c: usize,
    mean: &[f32],
    inv: &[f32],
    db: &mut [f64],
    dg: &mut [f64],
) {
    debug_assert_eq!(g.len(), rows * c);
    for c0 in (0..c).step_by(CBLK) {
        let cb = (c - c0).min(CBLK);
        let mut b = [0f64; CBLK];
        let mut gm = [0f64; CBLK];
        for r in 0..rows {
            for i in 0..cb {
                let ci = c0 + i;
                let gv = g[r * c + ci] as f64;
                let xhat = ((x[r * c + ci] - mean[ci]) * inv[ci]) as f64;
                b[i] += gv;
                gm[i] += gv * xhat;
            }
        }
        for i in 0..cb {
            db[c0 + i] += b[i];
            dg[c0 + i] += gm[i];
        }
    }
}

/// One shard's BN input cotangent against the globally-reduced
/// `dgamma`/`dbeta` sums, with `rows_total` the whole-batch row count
/// (the batch-statistics gradient couples every sample).
pub fn bn_bwd_apply_into(
    x: &[f32],
    g: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    mean: &[f32],
    inv: &[f32],
    dgamma: &[f32],
    dbeta: &[f32],
    rows_total: usize,
    dx: &mut [f32],
) {
    debug_assert_eq!(g.len(), rows * c);
    debug_assert_eq!(dx.len(), rows * c);
    let nf = rows_total as f32;
    for r in 0..rows {
        for ci in 0..c {
            let xhat = (x[r * c + ci] - mean[ci]) * inv[ci];
            let coeff = gamma[ci] * inv[ci] / nf;
            dx[r * c + ci] = coeff * (nf * g[r * c + ci] - dbeta[ci] - xhat * dgamma[ci]);
        }
    }
}

/// BatchNorm train-mode backward (compat wrapper). Returns
/// `(dx, dgamma, dbeta)`.
pub fn bn_bwd(
    x: &[f32],
    g: &[f32],
    rows: usize,
    c: usize,
    gamma: &[f32],
    cache: &BnCache,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut dx = vec![0f32; rows * c];
    let mut dgamma = vec![0f32; c];
    let mut dbeta = vec![0f32; c];
    bn_bwd_into(
        x,
        g,
        rows,
        c,
        gamma,
        &cache.mean,
        &cache.inv,
        &mut dx,
        &mut dgamma,
        &mut dbeta,
    );
    (dx, dgamma, dbeta)
}

// ------------------------------------------------------------- relu/pool

/// ReLU forward in place.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Mask the cotangent by the ReLU activation pattern of `pre` (the
/// pre-activation values).
pub fn relu_bwd_inplace(g: &mut [f32], pre: &[f32]) {
    for (gv, &p) in g.iter_mut().zip(pre.iter()) {
        if p <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Allocation-free 2×2 stride-2 max pool: writes the pooled output and
/// the argmax index (0..4, scan order (dy,dx)) per output element,
/// first max wins (matching XLA's select-and-scatter tie-break).
pub fn maxpool2_fwd_into(
    x: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [f32],
    arg: &mut [u8],
) {
    let (ho, wo) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), n * ho * wo * c);
    debug_assert_eq!(arg.len(), n * ho * wo * c);
    for bi in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ci in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u8;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let v = x[((bi * h + 2 * oy + dy) * w + 2 * ox + dx) * c + ci];
                            if v > best {
                                best = v;
                                bidx = (dy * 2 + dx) as u8;
                            }
                        }
                    }
                    let o = ((bi * ho + oy) * wo + ox) * c + ci;
                    out[o] = best;
                    arg[o] = bidx;
                }
            }
        }
    }
}

/// 2×2 stride-2 max pool (compat wrapper over [`maxpool2_fwd_into`]).
pub fn maxpool2_fwd(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> (Vec<f32>, Vec<u8>) {
    let (ho, wo) = (h / 2, w / 2);
    let mut out = vec![0f32; n * ho * wo * c];
    let mut arg = vec![0u8; n * ho * wo * c];
    maxpool2_fwd_into(x, n, h, w, c, &mut out, &mut arg);
    (out, arg)
}

/// Allocation-free backward of the max pool: zeroes `dx` and routes
/// each cotangent to its argmax. `h`/`w` are the *input* dimensions.
pub fn maxpool2_bwd_into(
    g: &[f32],
    arg: &[u8],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    dx: &mut [f32],
) {
    let (ho, wo) = (h / 2, w / 2);
    debug_assert_eq!(g.len(), n * ho * wo * c);
    debug_assert_eq!(dx.len(), n * h * w * c);
    dx.fill(0.0);
    for bi in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                for ci in 0..c {
                    let o = ((bi * ho + oy) * wo + ox) * c + ci;
                    let (dy, dx_) = ((arg[o] / 2) as usize, (arg[o] % 2) as usize);
                    dx[((bi * h + 2 * oy + dy) * w + 2 * ox + dx_) * c + ci] = g[o];
                }
            }
        }
    }
}

/// Backward of [`maxpool2_fwd`] (compat wrapper).
pub fn maxpool2_bwd(g: &[f32], arg: &[u8], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut dx = vec![0f32; n * h * w * c];
    maxpool2_bwd_into(g, arg, n, h, w, c, &mut dx);
    dx
}

/// Allocation-free global average pool over the spatial dims:
/// `(n,h,w,c)` -> `(n,c)`, f64 accumulation per channel.
pub fn gap_fwd_into(x: &[f32], n: usize, h: usize, w: usize, c: usize, out: &mut [f32]) {
    let hw = h * w;
    debug_assert_eq!(out.len(), n * c);
    for bi in 0..n {
        for c0 in (0..c).step_by(CBLK) {
            let cb = (c - c0).min(CBLK);
            let mut acc = [0f64; CBLK];
            for p in 0..hw {
                let base = (bi * hw + p) * c + c0;
                for (a, &v) in acc[..cb].iter_mut().zip(x[base..base + cb].iter()) {
                    *a += v as f64;
                }
            }
            for i in 0..cb {
                out[bi * c + c0 + i] = (acc[i] / hw as f64) as f32;
            }
        }
    }
}

/// Global average pool (compat wrapper over [`gap_fwd_into`]).
pub fn gap_fwd(x: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0f32; n * c];
    gap_fwd_into(x, n, h, w, c, &mut out);
    out
}

/// Allocation-free backward of the GAP: broadcast `g/(h*w)`.
pub fn gap_bwd_into(g: &[f32], n: usize, h: usize, w: usize, c: usize, dx: &mut [f32]) {
    let hw = h * w;
    let inv = 1.0 / hw as f32;
    debug_assert_eq!(dx.len(), n * hw * c);
    for bi in 0..n {
        for p in 0..hw {
            let base = (bi * hw + p) * c;
            for ci in 0..c {
                dx[base + ci] = g[bi * c + ci] * inv;
            }
        }
    }
}

/// Backward of [`gap_fwd`] (compat wrapper).
pub fn gap_bwd(g: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let mut dx = vec![0f32; n * h * w * c];
    gap_bwd_into(g, n, h, w, c, &mut dx);
    dx
}

// ----------------------------------------------------------------- dense

/// Dense layer forward: `x (n,cin) @ w (cin,cout) + b`, f32 accumulate
/// (bias preloaded, so per-element order matches the fused kernel).
pub fn dense_fwd(x: &[f32], n: usize, cin: usize, w: &[f32], cout: usize, b: &[f32]) -> Vec<f32> {
    with_exec(|ex| {
        let mut out = vec![0f32; n * cout];
        for r in 0..n {
            out[r * cout..(r + 1) * cout].copy_from_slice(b);
        }
        gemm::gemm(&ex.pool, &mut ex.arena, x, w, &mut out, n, cin, cout, true);
        out
    })
}

/// Dense backward matmuls: `dw = xᵀ g` and `dx = g wᵀ`, plus
/// `db = sum_rows g`. Matches the `mp_matmul` VJP structure (the
/// caller quantizes the operands per the layer code before calling).
pub fn dense_bwd(
    x: &[f32],
    n: usize,
    cin: usize,
    w: &[f32],
    cout: usize,
    g: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    with_exec(|ex| {
        let mut dx = vec![0f32; n * cin];
        gemm::gemm_a_bt(&ex.pool, &mut ex.arena, g, w, &mut dx, n, cout, cin, false);
        let mut dw = vec![0f32; cin * cout];
        gemm::gemm_at_b(&ex.pool, &mut ex.arena, x, g, &mut dw, n, cin, cout);
        let mut db = vec![0f32; cout];
        for bi in 0..n {
            for (d, &gv) in db.iter_mut().zip(g[bi * cout..(bi + 1) * cout].iter()) {
                *d += gv;
            }
        }
        (dx, dw, db)
    })
}

// --------------------------------------------------------------- softmax

/// Allocation-free mean softmax cross-entropy with int labels: writes
/// `dlogits = (softmax - onehot)/n` (the cotangent of the *unscaled*
/// mean loss) and returns `(loss, correct)`.
pub fn softmax_ce_into(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
    dlogits: &mut [f32],
) -> (f32, i64) {
    let (loss_sum, correct) = softmax_ce_sum_into(logits, y, n, classes, n, dlogits);
    ((loss_sum / n as f64) as f32, correct)
}

/// Shard form of the CE loss: `n` examples of a logical batch of
/// `n_total`. Writes `dlogits = (softmax - onehot)/n_total` and returns
/// the *unnormalized* f64 loss sum plus the correct count — the
/// replica orchestrator reduces shard sums in ascending shard order
/// and divides by `n_total` once. With `n_total == n` this is exactly
/// the mean-CE computation ([`softmax_ce_into`] wraps it).
pub fn softmax_ce_sum_into(
    logits: &[f32],
    y: &[i32],
    n: usize,
    classes: usize,
    n_total: usize,
    dlogits: &mut [f32],
) -> (f64, i64) {
    debug_assert_eq!(logits.len(), n * classes);
    debug_assert_eq!(dlogits.len(), n * classes);
    let mut loss_sum = 0f64;
    let mut correct = 0i64;
    for bi in 0..n {
        let row = &logits[bi * classes..(bi + 1) * classes];
        let mut m = f32::NEG_INFINITY;
        let mut argmax = 0usize;
        for (ci, &v) in row.iter().enumerate() {
            if v > m {
                m = v;
                argmax = ci;
            }
        }
        let mut z = 0f32;
        for &v in row.iter() {
            z += (v - m).exp();
        }
        let logz = z.ln() + m;
        let label = y[bi] as usize;
        loss_sum += (logz - row[label]) as f64;
        if argmax == label {
            correct += 1;
        }
        let drow = &mut dlogits[bi * classes..(bi + 1) * classes];
        for (ci, d) in drow.iter_mut().enumerate() {
            let p = (row[ci] - m).exp() / z;
            *d = (p - if ci == label { 1.0 } else { 0.0 }) / n_total as f32;
        }
    }
    (loss_sum, correct)
}

/// Mean softmax cross-entropy (compat wrapper over
/// [`softmax_ce_into`]). Returns `(loss, correct, dlogits)`.
pub fn softmax_ce(logits: &[f32], y: &[i32], n: usize, classes: usize) -> (f32, i64, Vec<f32>) {
    let mut dlogits = vec![0f32; n * classes];
    let (loss, correct) = softmax_ce_into(logits, y, n, classes, &mut dlogits);
    (loss, correct, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    /// Central-difference gradient check of a scalar loss built from
    /// the op under test. `f` maps (inputs) -> loss; `analytic` is the
    /// gradient produced by the backward pass. eps/floor/tol settings
    /// are tuned for f32 forward passes (FD noise ~1e-4 at this eps).
    fn gradcheck(name: &str, inputs: &mut [f32], analytic: &[f32], mut f: impl FnMut(&[f32]) -> f64) {
        let mut rng = Rng::new(0x6C);
        let checks = inputs.len().min(24);
        for _ in 0..checks {
            let i = rng.below(inputs.len() as u64) as usize;
            let eps = 3e-2f32;
            let orig = inputs[i];
            inputs[i] = orig + eps;
            let lp = f(inputs);
            inputs[i] = orig - eps;
            let lm = f(inputs);
            inputs[i] = orig;
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let diff = (numeric - analytic[i]).abs();
            let scale = numeric.abs().max(analytic[i].abs()).max(3e-2);
            assert!(
                diff / scale < 0.05,
                "{name}[{i}]: numeric {numeric} vs analytic {}",
                analytic[i]
            );
        }
    }

    /// Weighted-sum loss so cotangents are non-trivial but known.
    fn wsum(v: &[f32]) -> (f64, Vec<f32>) {
        let mut l = 0f64;
        let mut g = vec![0f32; v.len()];
        for (i, &x) in v.iter().enumerate() {
            let wgt = ((i % 7) as f32 - 3.0) * 0.25;
            l += (x * wgt) as f64;
            g[i] = wgt;
        }
        (l, g)
    }

    #[test]
    fn conv_gradcheck() {
        let (n, h, w, cin, cout) = (2, 4, 4, 3, 5);
        let mut rng = Rng::new(1);
        let mut x = randv(&mut rng, n * h * w * cin);
        let mut wt = randv(&mut rng, 9 * cin * cout);
        let out = conv3x3_fwd(&x, n, h, w, cin, &wt, cout);
        let (_, g) = wsum(&out);
        let (dx, dw) = conv3x3_bwd(&x, n, h, w, cin, &wt, cout, &g);
        let wt2 = wt.clone();
        gradcheck("conv/dx", &mut x, &dx, |xs| {
            wsum(&conv3x3_fwd(xs, n, h, w, cin, &wt2, cout)).0
        });
        let x2 = x.clone();
        gradcheck("conv/dw", &mut wt, &dw, |ws| {
            wsum(&conv3x3_fwd(&x2, n, h, w, cin, ws, cout)).0
        });
    }

    #[test]
    fn strided_conv_gradcheck() {
        let (n, h, w, cin, cout, k, s) = (2, 6, 6, 3, 4, 3, 2);
        let mut rng = Rng::new(21);
        let mut x = randv(&mut rng, n * h * w * cin);
        let mut wt = randv(&mut rng, k * k * cin * cout);
        let out = conv_fwd(&x, n, h, w, cin, &wt, cout, k, s);
        assert_eq!(out.len(), n * 3 * 3 * cout, "ceil(6/2) = 3 output side");
        let (_, g) = wsum(&out);
        let (dx, dw) = conv_bwd(&x, n, h, w, cin, &wt, cout, k, s, &g);
        let wt2 = wt.clone();
        gradcheck("sconv/dx", &mut x, &dx, |xs| {
            wsum(&conv_fwd(xs, n, h, w, cin, &wt2, cout, k, s)).0
        });
        let x2 = x.clone();
        gradcheck("sconv/dw", &mut wt, &dw, |ws| {
            wsum(&conv_fwd(&x2, n, h, w, cin, ws, cout, k, s)).0
        });
    }

    #[test]
    fn conv1x1_gradcheck_and_strided_identity() {
        let (n, h, w, cin, cout) = (2, 4, 4, 3, 5);
        let mut rng = Rng::new(22);
        let mut x = randv(&mut rng, n * h * w * cin);
        let mut wt = randv(&mut rng, cin * cout);
        let out = conv_fwd(&x, n, h, w, cin, &wt, cout, 1, 1);
        let (_, g) = wsum(&out);
        let (dx, dw) = conv_bwd(&x, n, h, w, cin, &wt, cout, 1, 1, &g);
        let wt2 = wt.clone();
        gradcheck("pw/dx", &mut x, &dx, |xs| {
            wsum(&conv_fwd(xs, n, h, w, cin, &wt2, cout, 1, 1)).0
        });
        let x2 = x.clone();
        gradcheck("pw/dw", &mut wt, &dw, |ws| {
            wsum(&conv_fwd(&x2, n, h, w, cin, ws, cout, 1, 1)).0
        });
        // Stride-2 1×1 with an identity-ish kernel subsamples the grid.
        let mut eye = vec![0f32; cin * cin];
        for i in 0..cin {
            eye[i * cin + i] = 1.0;
        }
        let sub = conv_fwd(&x, n, h, w, cin, &eye, cin, 1, 2);
        assert_eq!(&sub[0..cin], &x[0..cin], "out (0,0) is x[0,0]");
        assert_eq!(&sub[cin..2 * cin], &x[2 * cin..3 * cin], "out (0,1) is x[0,2]");
    }

    #[test]
    fn dwconv_gradcheck_both_strides() {
        for s in [1usize, 2] {
            let (n, h, w, c, k) = (2, 4, 4, 3, 3);
            let mut rng = Rng::new(23 + s as u64);
            let mut x = randv(&mut rng, n * h * w * c);
            let mut wt = randv(&mut rng, k * k * c);
            let out = dwconv_fwd(&x, n, h, w, c, k, s, &wt);
            let (_, g) = wsum(&out);
            let (dx, dw) = dwconv_bwd(&x, n, h, w, c, k, s, &wt, &g);
            let wt2 = wt.clone();
            gradcheck("dw/dx", &mut x, &dx, |xs| {
                wsum(&dwconv_fwd(xs, n, h, w, c, k, s, &wt2)).0
            });
            let x2 = x.clone();
            gradcheck("dw/dw", &mut wt, &dw, |ws| {
                wsum(&dwconv_fwd(&x2, n, h, w, c, k, s, ws)).0
            });
        }
    }

    #[test]
    fn dwconv_does_not_mix_channels() {
        // A filter that is zero on channel 1 must zero channel 1's
        // output while leaving channel 0 a pure channel-0 function.
        let (n, h, w, c, k) = (1, 3, 3, 2, 3);
        let mut rng = Rng::new(25);
        let x = randv(&mut rng, n * h * w * c);
        let mut wt = vec![0f32; k * k * c];
        wt[4 * c] = 2.0; // center tap, channel 0 only
        let out = dwconv_fwd(&x, n, h, w, c, k, 1, &wt);
        for p in 0..h * w {
            assert_eq!(out[p * c], 2.0 * x[p * c], "channel 0 is scaled");
            assert_eq!(out[p * c + 1], 0.0, "channel 1 untouched");
        }
    }

    #[test]
    fn bn_gradcheck() {
        let (rows, c) = (32, 4);
        let mut rng = Rng::new(2);
        let mut x = randv(&mut rng, rows * c);
        let mut gamma: Vec<f32> = (0..c).map(|i| 1.0 + 0.1 * i as f32).collect();
        let beta = randv(&mut rng, c);
        let rm = vec![0f32; c];
        let rv = vec![1f32; c];
        let run = |xs: &[f32], gm: &[f32]| {
            let (out, _, _, _) = bn_fwd(xs, rows, c, gm, &beta, &rm, &rv, true);
            wsum(&out).0
        };
        let (out, _, _, cache) = bn_fwd(&x, rows, c, &gamma, &beta, &rm, &rv, true);
        let (_, g) = wsum(&out);
        let (dx, dgamma, _dbeta) = bn_bwd(&x, &g, rows, c, &gamma, &cache);
        let gamma2 = gamma.clone();
        gradcheck("bn/dx", &mut x, &dx, |xs| run(xs, &gamma2));
        let x2 = x.clone();
        gradcheck("bn/dgamma", &mut gamma, &dgamma, |gm| run(&x2, gm));
    }

    #[test]
    fn bn_eval_uses_running_stats() {
        let (rows, c) = (8, 2);
        let mut rng = Rng::new(3);
        let x = randv(&mut rng, rows * c);
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let rm = vec![0.5f32; c];
        let rv = vec![2.0f32; c];
        let (out, nrm, nrv, _) = bn_fwd(&x, rows, c, &gamma, &beta, &rm, &rv, false);
        assert_eq!(nrm, rm, "eval must not touch running stats");
        assert_eq!(nrv, rv);
        let inv = 1.0 / (2.0f32 + BN_EPS).sqrt();
        assert!((out[0] - (x[0] - 0.5) * inv).abs() < 1e-6);
    }

    #[test]
    fn bn_train_updates_running_stats() {
        let (rows, c) = (64, 1);
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..rows).map(|_| 3.0 + rng.next_normal()).collect();
        let (_, nrm, nrv, _) =
            bn_fwd(&x, rows, c, &[1.0], &[0.0], &[0.0], &[1.0], true);
        // torch-style: running <- 0.9*running + 0.1*batch.
        assert!(nrm[0] > 0.2 && nrm[0] < 0.4, "rm {}", nrm[0]);
        assert!(nrv[0] > 0.9, "rv {}", nrv[0]);
    }

    #[test]
    fn bn_blocked_stats_cover_wide_channel_counts() {
        // c > CBLK exercises the block seam; compare against a direct
        // per-channel f64 reference.
        let (rows, c) = (16, CBLK + 3);
        let mut rng = Rng::new(40);
        let x = randv(&mut rng, rows * c);
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let rm = vec![0f32; c];
        let rv = vec![1f32; c];
        let (_, _, _, cache) = bn_fwd(&x, rows, c, &gamma, &beta, &rm, &rv, true);
        for ci in [0usize, CBLK - 1, CBLK, c - 1] {
            let mut s = 0f64;
            for r in 0..rows {
                s += x[r * c + ci] as f64;
            }
            let want = (s / rows as f64) as f32;
            assert!((cache.mean[ci] - want).abs() < 1e-6, "channel {ci}");
        }
    }

    #[test]
    fn sharded_bn_is_shard_count_invariant() {
        // The replica-path contract: partial stats reduced in ascending
        // shard order give bit-identical results for any contiguous
        // shard split of the same rows.
        let (rows, c) = (48, CBLK + 5);
        let mut rng = Rng::new(51);
        let x = randv(&mut rng, rows * c);
        let run = |bounds: &[usize]| {
            let mut sum = vec![0f64; c];
            let mut sq = vec![0f64; c];
            for w in bounds.windows(2) {
                bn_partial_into(&x[w[0] * c..w[1] * c], w[1] - w[0], c, &mut sum, &mut sq);
            }
            let rm = vec![0f32; c];
            let rv = vec![1f32; c];
            let (mut mean, mut inv) = (vec![0f32; c], vec![0f32; c]);
            let (mut nrm, mut nrv) = (vec![0f32; c], vec![0f32; c]);
            bn_finalize_stats(&sum, &sq, rows, &rm, &rv, &mut mean, &mut inv, &mut nrm, &mut nrv);
            (mean, inv, nrm, nrv)
        };
        let whole = run(&[0, rows]);
        assert_eq!(whole, run(&[0, 12, 24, 36, rows]), "4 shards == 1 shard");
        assert_eq!(whole, run(&[0, 24, rows]), "2 shards == 1 shard");
        // And the one-pass stats agree with the two-pass fused path to
        // f32 round-off (they are distinct numeric contracts).
        let gamma = vec![1f32; c];
        let beta = vec![0f32; c];
        let (_, _, _, cache) =
            bn_fwd(&x, rows, c, &gamma, &beta, &vec![0f32; c], &vec![1f32; c], true);
        for ci in 0..c {
            assert!((whole.0[ci] - cache.mean[ci]).abs() < 1e-5, "mean channel {ci}");
            assert!((whole.1[ci] / cache.inv[ci] - 1.0).abs() < 1e-4, "inv channel {ci}");
        }
    }

    #[test]
    fn sharded_bn_backward_matches_fused() {
        let (rows, c) = (32, 6);
        let mut rng = Rng::new(52);
        let x = randv(&mut rng, rows * c);
        let g = randv(&mut rng, rows * c);
        let gamma: Vec<f32> = (0..c).map(|i| 1.0 + 0.05 * i as f32).collect();
        let (_, _, _, cache) =
            bn_fwd(&x, rows, c, &gamma, &vec![0f32; c], &vec![0f32; c], &vec![1f32; c], true);
        let (dx_ref, dgamma_ref, dbeta_ref) = bn_bwd(&x, &g, rows, c, &gamma, &cache);
        // Sharded: partials reduced over 2 shards, apply per shard.
        let mut db = vec![0f64; c];
        let mut dg = vec![0f64; c];
        let mid = rows / 2;
        for (r0, r1) in [(0, mid), (mid, rows)] {
            bn_bwd_partial_into(
                &x[r0 * c..r1 * c],
                &g[r0 * c..r1 * c],
                r1 - r0,
                c,
                &cache.mean,
                &cache.inv,
                &mut db,
                &mut dg,
            );
        }
        let dgamma: Vec<f32> = dg.iter().map(|&v| v as f32).collect();
        let dbeta: Vec<f32> = db.iter().map(|&v| v as f32).collect();
        let mut dx = vec![0f32; rows * c];
        for (r0, r1) in [(0, mid), (mid, rows)] {
            bn_bwd_apply_into(
                &x[r0 * c..r1 * c],
                &g[r0 * c..r1 * c],
                r1 - r0,
                c,
                &gamma,
                &cache.mean,
                &cache.inv,
                &dgamma,
                &dbeta,
                rows,
                &mut dx[r0 * c..r1 * c],
            );
        }
        for ci in 0..c {
            assert!((dgamma[ci] - dgamma_ref[ci]).abs() < 1e-4, "dgamma {ci}");
            assert!((dbeta[ci] - dbeta_ref[ci]).abs() < 1e-4, "dbeta {ci}");
        }
        for i in 0..rows * c {
            assert!((dx[i] - dx_ref[i]).abs() < 1e-4, "dx[{i}]");
        }
    }

    #[test]
    fn shard_softmax_sums_compose_to_the_mean() {
        let (n, classes) = (8, 5);
        let mut rng = Rng::new(53);
        let logits = randv(&mut rng, n * classes);
        let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
        let mut d_ref = vec![0f32; n * classes];
        let (loss_ref, corr_ref) = softmax_ce_into(&logits, &y, n, classes, &mut d_ref);
        let mut d_sh = vec![0f32; n * classes];
        let mut loss_sum = 0f64;
        let mut corr = 0i64;
        for (r0, r1) in [(0usize, 3usize), (3, 5), (5, n)] {
            let (ls, cr) = softmax_ce_sum_into(
                &logits[r0 * classes..r1 * classes],
                &y[r0..r1],
                r1 - r0,
                classes,
                n,
                &mut d_sh[r0 * classes..r1 * classes],
            );
            loss_sum += ls;
            corr += cr;
        }
        assert_eq!(corr, corr_ref);
        assert_eq!(d_sh, d_ref, "per-example cotangents are shard-independent");
        assert!((((loss_sum / n as f64) as f32) - loss_ref).abs() < 1e-6);
    }

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        let (n, h, w, c) = (1, 4, 4, 1);
        let mut x = vec![0f32; 16];
        x[5] = 7.0; // window (0,0) interior max at (1,1)
        x[2] = 3.0; // window (0,1) max at (0,2) -> arg 0
        let (out, arg) = maxpool2_fwd(&x, n, h, w, c);
        assert_eq!(out[0], 7.0);
        assert_eq!(arg[0], 3);
        assert_eq!(out[1], 3.0);
        assert_eq!(arg[1], 0);
        let g = vec![1f32, 2.0, 3.0, 4.0];
        let dx = maxpool2_bwd(&g, &arg, n, h, w, c);
        assert_eq!(dx[5], 1.0);
        assert_eq!(dx[2], 2.0);
        assert_eq!(dx.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn maxpool_tie_breaks_to_first() {
        let x = vec![2f32, 2.0, 2.0, 2.0];
        let (_, arg) = maxpool2_fwd(&x, 1, 2, 2, 1);
        assert_eq!(arg[0], 0, "ties go to the first scanned element");
    }

    #[test]
    fn maxpool_bwd_into_rezeroes_dirty_buffers() {
        let x = vec![1f32, 2.0, 3.0, 4.0];
        let (_, arg) = maxpool2_fwd(&x, 1, 2, 2, 1);
        let mut dx = vec![9f32; 4]; // dirty scratch
        maxpool2_bwd_into(&[5.0], &arg, 1, 2, 2, 1, &mut dx);
        assert_eq!(dx, vec![0.0, 0.0, 0.0, 5.0]);
    }

    #[test]
    fn gap_roundtrip() {
        let (n, h, w, c) = (2, 2, 2, 3);
        let mut rng = Rng::new(5);
        let x = randv(&mut rng, n * h * w * c);
        let out = gap_fwd(&x, n, h, w, c);
        // detlint: ordered — sequential sum over ascending positions.
        let manual: f32 = (0..4).map(|p| x[p * c]).sum::<f32>() / 4.0;
        assert!((out[0] - manual).abs() < 1e-6);
        let g: Vec<f32> = (0..n * c).map(|i| i as f32).collect();
        let dx = gap_bwd(&g, n, h, w, c);
        assert!((dx[0] - 0.0).abs() < 1e-7);
        assert!((dx[c] - 0.0).abs() < 1e-7);
        assert!((dx[1] - 0.25).abs() < 1e-7, "g=1 spread over 4 pixels");
    }

    #[test]
    fn dense_gradcheck() {
        let (n, cin, cout) = (4, 6, 5);
        let mut rng = Rng::new(6);
        let mut x = randv(&mut rng, n * cin);
        let mut w = randv(&mut rng, cin * cout);
        let b = randv(&mut rng, cout);
        let out = dense_fwd(&x, n, cin, &w, cout, &b);
        let (_, g) = wsum(&out);
        let (dx, dw, db) = dense_bwd(&x, n, cin, &w, cout, &g);
        let w2 = w.clone();
        let b2 = b.clone();
        gradcheck("dense/dx", &mut x, &dx, |xs| {
            wsum(&dense_fwd(xs, n, cin, &w2, cout, &b2)).0
        });
        let x2 = x.clone();
        gradcheck("dense/dw", &mut w, &dw, |ws| {
            wsum(&dense_fwd(&x2, n, cin, ws, cout, &b2)).0
        });
        // db is the column sum of g.
        for co in 0..cout {
            // detlint: ordered — sequential sum over ascending batch rows.
            let want: f32 = (0..n).map(|bi| g[bi * cout + co]).sum();
            assert!((db[co] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_ce_gradcheck_and_counts() {
        let (n, classes) = (6, 4);
        let mut rng = Rng::new(7);
        let mut logits = randv(&mut rng, n * classes);
        let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
        let (loss, correct, dlogits) = softmax_ce(&logits, &y, n, classes);
        assert!(loss > 0.0 && loss.is_finite());
        assert!((0..=n as i64).contains(&correct));
        gradcheck("ce/dlogits", &mut logits, &dlogits, |ls| {
            softmax_ce(ls, &y, n, classes).0 as f64
        });
        // Perfect logits -> full correct count, tiny loss.
        let mut perfect = vec![0f32; n * classes];
        for (bi, &label) in y.iter().enumerate() {
            perfect[bi * classes + label as usize] = 30.0;
        }
        let (l2, c2, _) = softmax_ce(&perfect, &y, n, classes);
        assert_eq!(c2, n as i64);
        assert!(l2 < 1e-6);
    }

    #[test]
    fn conv_zero_padding_at_borders() {
        // A single centered weight (identity kernel) must reproduce x.
        let (n, h, w, cin, cout) = (1, 3, 3, 1, 1);
        let x: Vec<f32> = (1..=9).map(|v| v as f32).collect();
        let mut wt = vec![0f32; 9];
        wt[4] = 1.0; // (ky=1, kx=1)
        let out = conv3x3_fwd(&x, n, h, w, cin, &wt, cout);
        assert_eq!(out, x);
        // A corner weight reads the zero-padded halo at the borders.
        let mut wt2 = vec![0f32; 9];
        wt2[0] = 1.0; // (ky=0, kx=0) -> reads (y-1, x-1)
        let out2 = conv3x3_fwd(&x, n, h, w, cin, &wt2, cout);
        assert_eq!(out2[0], 0.0, "top-left reads the halo");
        assert_eq!(out2[4], 1.0, "center reads x[0,0]");
    }
}
