//! The method registry: named policy compositions resolved at
//! arg-parse time. The paper's three Table-1 columns are the first
//! three entries; the rest are compositions the pluggable policy plane
//! makes cheap to add (the Table-2 ablation rows, a loss-scale-only
//! AMP, an elasticity-only method for the VRAM-pressure scenarios).
//!
//! A spec is declarative: a Table-1 *family* (which names the metrics
//! row), the §3 ablation toggles, and an optional precision pin. The
//! plane (`ControlPlane::new`) turns the resolved config into the
//! policy triple. `registry()` is the single source of truth for
//! `--method` parsing, `--list-methods`, and checkpoint
//! method-compatibility keys.

use anyhow::Result;

use crate::config::{Ablation, Config, Method};
use crate::manifest::{BF16, FP16, FP32};

/// One named method: a policy composition the CLI can select.
#[derive(Debug, Clone, Copy)]
pub struct MethodSpec {
    /// Registry key (`--method <key>`), also the checkpoint method id.
    pub key: &'static str,
    /// Accepted alternate spellings.
    pub aliases: &'static [&'static str],
    /// Display label (Table-1 style).
    pub label: &'static str,
    /// Table-1 family the summary rows file this method under.
    pub family: Method,
    pub ablation: Ablation,
    /// Pinned precision code for the non-adaptive precision policy;
    /// `None` = the family default (FP32 baseline pins FP32, everything
    /// else pins BF16 when dynamic precision is off).
    pub pin: Option<i32>,
    /// Let the control plane elastically shed/restore data-parallel
    /// replicas under VRAM pressure (requires `--replicas > 1` to have
    /// any effect; replica moves never change training numerics).
    pub elastic_replicas: bool,
    /// One-line description for `--list-methods`.
    pub about: &'static str,
}

/// Every named method, in presentation order.
pub const REGISTRY: &[MethodSpec] = &[
    MethodSpec {
        key: "fp32",
        aliases: &[],
        label: "FP32 Baseline",
        family: Method::Fp32,
        ablation: Ablation { dynamic_precision: false, dynamic_batch: false, curvature: false },
        pin: None,
        elastic_replicas: false,
        about: "FP32 SGD+momentum, fixed batch, no adaptivity",
    },
    MethodSpec {
        key: "amp_static",
        aliases: &["amp"],
        label: "AMP (Static)",
        family: Method::AmpStatic,
        ablation: Ablation { dynamic_precision: false, dynamic_batch: false, curvature: false },
        pin: None,
        elastic_replicas: false,
        about: "uniform BF16 compute, dynamic loss scale, fixed batch",
    },
    MethodSpec {
        key: "tri_accel",
        aliases: &["tri-accel", "triaccel"],
        label: "Tri-Accel",
        family: Method::TriAccel,
        ablation: Ablation { dynamic_precision: true, dynamic_batch: true, curvature: true },
        pin: None,
        elastic_replicas: false,
        about: "full §3.4 loop: adaptive precision × curvature × elastic batch",
    },
    MethodSpec {
        key: "tri_accel_nocurv",
        aliases: &["tri-accel-nocurv"],
        label: "Tri-Accel (no curv)",
        family: Method::TriAccel,
        ablation: Ablation { dynamic_precision: true, dynamic_batch: true, curvature: false },
        pin: None,
        elastic_replicas: false,
        about: "adaptive precision + elastic batch, curvature probes off",
    },
    MethodSpec {
        key: "amp_dynamic",
        aliases: &["amp-dynamic", "amp_fp16"],
        label: "AMP (Dynamic)",
        family: Method::AmpStatic,
        ablation: Ablation { dynamic_precision: false, dynamic_batch: false, curvature: false },
        pin: Some(FP16),
        elastic_replicas: false,
        about: "uniform FP16 compute driven by the dynamic loss scale alone",
    },
    MethodSpec {
        key: "greedy_batch",
        aliases: &["greedy-batch", "batch_only"],
        label: "Greedy Batch",
        family: Method::TriAccel,
        ablation: Ablation { dynamic_precision: false, dynamic_batch: true, curvature: false },
        pin: None,
        elastic_replicas: false,
        about: "elasticity only: pinned BF16, batch follows the VRAM signal",
    },
    MethodSpec {
        key: "tri_accel_replica",
        aliases: &["tri-accel-replica", "triaccel_replica"],
        label: "Tri-Accel (elastic replicas)",
        family: Method::TriAccel,
        ablation: Ablation { dynamic_precision: true, dynamic_batch: true, curvature: true },
        pin: None,
        elastic_replicas: true,
        about: "full loop + elastic data-parallel replica count under VRAM pressure",
    },
];

/// The registry (presentation order).
pub fn registry() -> &'static [MethodSpec] {
    REGISTRY
}

/// Resolve a CLI name to a spec; unknown names list the full registry.
pub fn resolve(name: &str) -> Result<&'static MethodSpec> {
    if let Some(spec) = REGISTRY
        .iter()
        .find(|s| s.key == name || s.aliases.contains(&name))
    {
        return Ok(spec);
    }
    let known: Vec<String> = REGISTRY
        .iter()
        .map(|s| {
            if s.aliases.is_empty() {
                s.key.to_string()
            } else {
                format!("{} ({})", s.key, s.aliases.join(", "))
            }
        })
        .collect();
    anyhow::bail!(
        "unknown method `{name}` — registered methods: {}",
        known.join(", ")
    )
}

/// Apply a spec to a config: family, ablation toggles, precision pin,
/// elastic-replica control. (`cfg.replicas` itself is workload shape,
/// not method — `--replicas` sets it independently.)
pub fn apply(cfg: &mut Config, spec: &MethodSpec) {
    cfg.method = spec.family;
    cfg.ablation = spec.ablation;
    cfg.pin_override = spec.pin;
    cfg.elastic_replicas = spec.elastic_replicas;
}

/// The registry key describing a config's *effective* method — the
/// composition actually built, after the ablation flags and pin
/// override (which tests and `--set` mutate freely) are taken into
/// account. Compositions with no registered name get a synthesized
/// `tri_accel[p.b.c]`-style key. Used as the checkpoint method id.
pub fn effective_key(cfg: &Config) -> String {
    // Compare against the *normalized* composition the plane actually
    // builds: non-TriAccel families ignore the ablation flags, and an
    // adaptive-precision composition ignores the pin override.
    let ablation = match cfg.method {
        Method::TriAccel => cfg.ablation,
        _ => Ablation::none(),
    };
    let pin_override = if cfg.method == Method::TriAccel && ablation.dynamic_precision {
        None
    } else {
        cfg.pin_override
    };
    for s in REGISTRY {
        if s.family == cfg.method
            && s.ablation == ablation
            && s.pin == pin_override
            && s.elastic_replicas == cfg.elastic_replicas
        {
            return s.key.to_string();
        }
    }
    let pin = match pin_override {
        None => "auto".to_string(),
        Some(c) if c == FP16 => "fp16".into(),
        Some(c) if c == BF16 => "bf16".into(),
        Some(c) if c == FP32 => "fp32".into(),
        Some(c) => format!("code{c}"),
    };
    format!(
        "{}[p{}b{}c{}r{}&pin={pin}]",
        match cfg.method {
            Method::Fp32 => "fp32",
            Method::AmpStatic => "amp_static",
            Method::TriAccel => "tri_accel",
        },
        ablation.dynamic_precision as u8,
        ablation.dynamic_batch as u8,
        ablation.curvature as u8,
        cfg.elastic_replicas as u8,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_names_resolve_to_legacy_specs() {
        assert_eq!(resolve("fp32").unwrap().family, Method::Fp32);
        assert_eq!(resolve("amp").unwrap().key, "amp_static");
        assert_eq!(resolve("tri-accel").unwrap().key, "tri_accel");
        assert!(resolve("tri_accel").unwrap().ablation.curvature);
    }

    #[test]
    fn unknown_method_lists_registry() {
        let err = resolve("adam").unwrap_err().to_string();
        for s in REGISTRY {
            assert!(err.contains(s.key), "error must list `{}`: {err}", s.key);
        }
    }

    #[test]
    fn keys_and_aliases_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for s in REGISTRY {
            assert!(seen.insert(s.key), "duplicate key {}", s.key);
            for &a in s.aliases {
                assert!(seen.insert(a), "duplicate alias {a}");
            }
        }
    }

    #[test]
    fn apply_then_effective_key_roundtrips() {
        for s in REGISTRY {
            let mut cfg = Config::default();
            apply(&mut cfg, s);
            assert_eq!(effective_key(&cfg), s.key, "spec {} must round-trip", s.key);
        }
    }

    #[test]
    fn legacy_config_paths_map_to_registry_keys() {
        // Config::cell + ablation mutation — the pre-registry way the
        // harness builds the Table-2 rows — still lands on named specs.
        let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 0);
        assert_eq!(effective_key(&cfg), "tri_accel");
        cfg.ablation.curvature = false;
        assert_eq!(effective_key(&cfg), "tri_accel_nocurv");
        cfg.ablation.dynamic_precision = false;
        assert_eq!(effective_key(&cfg), "greedy_batch");
        // Non-TriAccel families ignore stale ablation flags.
        let mut amp = Config::cell("tiny_cnn_c10", Method::AmpStatic, 0);
        amp.ablation = Ablation::full();
        assert_eq!(effective_key(&amp), "amp_static");
    }

    #[test]
    fn adaptive_compositions_ignore_the_pin_override() {
        // `pin` is documented as inert when dynamic precision is
        // active; two bit-identical compositions must share a key (a
        // checkpoint saved without the flag resumes with it set).
        let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 0);
        cfg.pin_override = Some(BF16);
        assert_eq!(effective_key(&cfg), "tri_accel");
    }

    #[test]
    fn unnamed_compositions_get_synthesized_keys() {
        let mut cfg = Config::cell("tiny_cnn_c10", Method::TriAccel, 0);
        cfg.ablation =
            Ablation { dynamic_precision: true, dynamic_batch: false, curvature: true };
        let key = effective_key(&cfg);
        assert!(key.starts_with("tri_accel[p1b0c1"), "got {key}");
    }
}
