//! Loader for the canonical CIFAR binary distributions
//! (`cifar-10-batches-bin`: 5×10000 train records of 1+3072 bytes CHW;
//! `cifar-100-binary`: train.bin/test.bin with 2 label bytes). Used
//! automatically when the directory exists (DESIGN.md §5); otherwise the
//! synthetic generator stands in.

use std::path::Path;

use anyhow::{Context, Result};

use super::{Dataset, IMG_C, IMG_ELEMS, IMG_H, IMG_W, MEAN, STD};

pub struct CifarBin {
    num_classes: usize,
    /// Raw records: label(s) + CHW pixels, contiguous.
    data: Vec<u8>,
    record: usize,
    label_off: usize,
    len: usize,
}

impl CifarBin {
    pub fn load(dir: &Path, num_classes: usize, train: bool) -> Result<CifarBin> {
        let (files, label_bytes): (Vec<String>, usize) = match (num_classes, train) {
            (10, true) => (
                (1..=5).map(|i| format!("data_batch_{i}.bin")).collect(),
                1,
            ),
            (10, false) => (vec!["test_batch.bin".into()], 1),
            (100, true) => (vec!["train.bin".into()], 2),
            (100, false) => (vec!["test.bin".into()], 2),
            _ => anyhow::bail!("unsupported num_classes {num_classes}"),
        };
        let record = label_bytes + IMG_ELEMS;
        let mut data = Vec::new();
        for f in &files {
            let p = dir.join(f);
            let bytes =
                std::fs::read(&p).with_context(|| format!("reading CIFAR binary {p:?}"))?;
            anyhow::ensure!(bytes.len() % record == 0, "{p:?}: truncated records");
            data.extend_from_slice(&bytes);
        }
        let len = data.len() / record;
        anyhow::ensure!(len > 0, "no records in {dir:?}");
        Ok(CifarBin {
            num_classes,
            data,
            record,
            // CIFAR-100 records are [coarse, fine, pixels]; fine is the
            // 100-way label.
            label_off: label_bytes - 1,
            len,
        })
    }
}

impl Dataset for CifarBin {
    fn len(&self) -> usize {
        self.len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn example(&self, idx: usize, out: &mut [f32]) -> i32 {
        let rec = &self.data[idx * self.record..(idx + 1) * self.record];
        let label = rec[self.label_off] as i32;
        let px = &rec[self.record - IMG_ELEMS..];
        // CHW u8 → normalized NHWC f32.
        for c in 0..IMG_C {
            for y in 0..IMG_H {
                for x in 0..IMG_W {
                    let raw = px[c * IMG_H * IMG_W + y * IMG_W + x] as f32 / 255.0;
                    out[(y * IMG_W + x) * IMG_C + c] = (raw - MEAN[c]) / STD[c];
                }
            }
        }
        label
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cifar10_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("triaccel_cifar_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Two records per batch file: label i, pixels = i everywhere.
        for f in 1..=5 {
            let mut bytes = Vec::new();
            for r in 0..2u8 {
                bytes.push((f as u8 + r) % 10); // label
                bytes.extend(std::iter::repeat(10 * f as u8 + r).take(IMG_ELEMS));
            }
            std::fs::write(dir.join(format!("data_batch_{f}.bin")), &bytes).unwrap();
        }
        std::fs::write(
            dir.join("test_batch.bin"),
            {
                let mut b = vec![7u8];
                b.extend(std::iter::repeat(128u8).take(IMG_ELEMS));
                b
            },
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_and_decodes_cifar10_layout() {
        let dir = fake_cifar10_dir();
        let ds = CifarBin::load(&dir, 10, true).unwrap();
        assert_eq!(ds.len(), 10, "5 files × 2 records");
        let mut buf = vec![0f32; IMG_ELEMS];
        let l = ds.example(0, &mut buf);
        assert_eq!(l, 1);
        // Constant image 10/255 normalized on channel 0.
        let want = (10.0 / 255.0 - MEAN[0]) / STD[0];
        assert!((buf[0] - want).abs() < 1e-6);
        let test = CifarBin::load(&dir, 10, false).unwrap();
        assert_eq!(test.len(), 1);
        let lt = test.example(0, &mut buf);
        assert_eq!(lt, 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_errors() {
        assert!(CifarBin::load(Path::new("/nonexistent/xyz"), 10, true).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let dir = std::env::temp_dir().join(format!("triaccel_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("test_batch.bin"), vec![0u8; 100]).unwrap();
        assert!(CifarBin::load(&dir, 10, false).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cifar100_uses_fine_label() {
        let dir = std::env::temp_dir().join(format!("triaccel_c100_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut bytes = vec![3u8, 42u8]; // coarse=3, fine=42
        bytes.extend(std::iter::repeat(0u8).take(IMG_ELEMS));
        std::fs::write(dir.join("train.bin"), &bytes).unwrap();
        let ds = CifarBin::load(&dir, 100, true).unwrap();
        let mut buf = vec![0f32; IMG_ELEMS];
        assert_eq!(ds.example(0, &mut buf), 42);
        std::fs::remove_dir_all(&dir).ok();
    }
}
