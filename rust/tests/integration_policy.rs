//! End-to-end coverage of the policy subsystem: registry methods
//! beyond the paper's three columns run through the unmodified
//! trainer, the VRAM-pressure scenarios (hand-rolled traces and the
//! named adversarial library) separate static from elastic methods,
//! elastic data-parallel replicas shed under a ramping squeeze with
//! zero simulated OOMs, and the v3 checkpoint compatibility header
//! rejects method/graph mismatches with clear errors.

use tri_accel::config::Config;
use tri_accel::harness;
use tri_accel::manifest::{BF16, FP16};
use tri_accel::memsim::scenarios::ScenarioKind;
use tri_accel::memsim::VramSim;
use tri_accel::policy::registry;
use tri_accel::runtime::Engine;
use tri_accel::train::Trainer;

fn engine() -> Engine {
    Engine::native()
}

/// Quick config for a named registry method.
fn quick_cfg(method_key: &str, seed: u64) -> Config {
    let spec = registry::resolve(method_key).unwrap();
    let mut cfg = Config::cell("tiny_cnn_c10", spec.family, seed);
    registry::apply(&mut cfg, spec);
    cfg.epochs = 1;
    cfg.steps_per_epoch = Some(25);
    cfg.train_examples = 2048;
    cfg.eval_examples = 256;
    cfg.batch_init = 16;
    cfg.t_ctrl = 5;
    cfg.t_curv = 10;
    cfg.curv_warmup = 1;
    cfg.batch_cooldown = 5;
    cfg.warmup_epochs = 0;
    cfg.mem_budget_gb = 0.06;
    cfg.mem_noise = 0.0;
    cfg
}

#[test]
fn amp_dynamic_trains_uniform_fp16_with_live_scaler() {
    let e = engine();
    let mut tr = Trainer::new(&e, quick_cfg("amp_dynamic", 0)).unwrap();
    let r = tr.run_epoch(0).unwrap();
    assert!(r.train_loss.is_finite() && r.train_loss > 0.0);
    assert!(tr.controller.codes().iter().all(|&c| c == FP16), "uniform FP16");
    assert_eq!(r.mix.fp16, 1.0);
    // FP16 everywhere ⇒ the loss scale actually reaches the graph.
    assert!(tr.controller.loss_scale() >= 1.0);
    assert_eq!(tr.metrics.batch_trace.len(), 1, "batch stays fixed");
    assert_eq!(tr.metrics.curv_firings, 0, "no curvature policy");
}

#[test]
fn greedy_batch_is_elastic_with_pinned_bf16() {
    let e = engine();
    let mut cfg = quick_cfg("greedy_batch", 1);
    cfg.mem_budget_gb = 0.5; // roomy: the ladder should climb
    cfg.steps_per_epoch = Some(40);
    cfg.batch_cooldown = 3;
    let mut tr = Trainer::new(&e, cfg).unwrap();
    tr.run_epoch(0).unwrap();
    assert!(tr.controller.codes().iter().all(|&c| c == BF16), "precision pinned");
    let max_b = tr.metrics.batch_trace.iter().map(|&(_, b)| b).max().unwrap();
    assert!(max_b > 16, "elastic policy never grew the batch");
    assert!(tr.metrics.batch_decisions > 0);
    assert_eq!(tr.metrics.curv_firings, 0);
}

#[test]
fn tri_accel_nocurv_adapts_precision_without_probes() {
    let e = engine();
    let mut tr = Trainer::new(&e, quick_cfg("tri_accel_nocurv", 2)).unwrap();
    tr.run_epoch(0).unwrap();
    assert_eq!(tr.metrics.curv_firings, 0, "curvature off");
    assert_eq!(tr.metrics.promotions, 0);
    assert!(tr.controller.lr_scales().iter().all(|&s| s == 1.0), "no λ ⇒ unit scales");
    assert!(tr.metrics.ctrl_windows > 0, "control windows still run");
}

#[test]
fn pressure_sweep_separates_static_from_elastic() {
    // Calibrate the squeeze from the simulator itself so the scenario
    // is exact on any geometry: base budget fits B=64 comfortably; the
    // squeezed budget sits midway between the B=32 and B=64 footprints
    // (half-precision codes — amp_dynamic and greedy_batch both run
    // 2-byte compute). A static method must then OOM on every step
    // after the squeeze; the elastic method sheds buckets and recovers.
    let e = engine();
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    let u64gb = sim.usage(64, &codes, false).total_gb;
    let u32gb = sim.usage(32, &codes, false).total_gb;
    let base = u64gb * 1.2;
    let squeezed = 0.5 * (u32gb + u64gb);
    let trace = format!("step:{:.8}@10", squeezed / base);

    let tweak = move |cfg: &mut Config| {
        cfg.epochs = 1;
        cfg.steps_per_epoch = Some(30);
        cfg.train_examples = 4096;
        cfg.eval_examples = 128;
        cfg.batch_init = 64;
        cfg.t_ctrl = 3;
        cfg.t_curv = 0; // no probes: keep the footprint pure
        cfg.batch_cooldown = 2;
        cfg.warmup_epochs = 0;
        cfg.mem_budget_gb = base;
        cfg.mem_noise = 0.0;
    };
    let rows = harness::pressure(
        &e,
        "tiny_cnn_c10",
        &["amp_dynamic", "greedy_batch"],
        &[0],
        &trace,
        &tweak,
    )
    .unwrap();
    assert_eq!(rows.len(), 2);
    let stat = &rows[0];
    let elastic = &rows[1];
    assert_eq!(stat.method_key, "amp_dynamic");
    assert!(
        stat.oom_events > 5,
        "static batch must OOM under the squeeze, got {}",
        stat.oom_events
    );
    assert_eq!(stat.min_batch, 64, "static method never sheds");
    assert!(elastic.min_batch < 64, "elastic method sheds buckets");
    assert!(
        elastic.oom_events < stat.oom_events,
        "elastic ({}) must OOM less than static ({})",
        elastic.oom_events,
        stat.oom_events
    );
    assert!(elastic.acc.mean().is_finite());
}

#[test]
fn elastic_replicas_shed_under_a_ramp_with_zero_ooms() {
    // Calibrate from the simulator: the base budget holds 4 replicas
    // with ~20% headroom; a slow ramp squeezes it to where only 2 fit.
    // Because the ramp descends gently relative to the control cadence,
    // the replica controller always sheds at a window *before* the live
    // footprint outgrows the budget — so the squeeze is absorbed with
    // zero simulated OOMs, and the batch ladder never has to move
    // first (replicas are the numerics-free lever).
    let e = Engine::native_replicated(4, 1);
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    sim.set_replicas(4);
    let u4 = sim.usage(64, &codes, false).total_gb;
    sim.set_replicas(2);
    let u2 = sim.usage(64, &codes, false).total_gb;
    let base = u4 * 1.25;
    // End the ramp where 2 replicas sit at ~85% occupancy: high enough
    // that a 4-replica restore is vetoed, low enough to hold steady.
    let f_end = (u2 / 0.85) / base;
    let trace = format!("ramp:8:38:{f_end:.8}");

    let mut cfg = quick_cfg("greedy_batch", 0); // pinned BF16: pure footprint
    cfg.replicas = 4;
    cfg.elastic_replicas = true;
    cfg.batch_init = 64;
    cfg.steps_per_epoch = Some(45);
    cfg.t_ctrl = 2;
    cfg.t_curv = 0;
    cfg.batch_cooldown = 2;
    cfg.mem_budget_gb = base;
    cfg.mem_trace = trace;
    let mut tr = Trainer::new(&e, cfg).unwrap();
    tr.run_epoch(0).unwrap();
    assert_eq!(tr.metrics.oom_events, 0, "shedding must pre-empt every OOM");
    assert!(tr.metrics.replica_decisions > 0, "the replica policy acted");
    assert!(
        tr.controller.replicas() < 4,
        "the squeeze persists, so the shed must too (live: {})",
        tr.controller.replicas()
    );
    assert!(tr.controller.replicas() >= 1);
}

#[test]
fn tri_accel_replica_method_runs_the_full_loop_replicated() {
    let e = Engine::native_replicated(2, 1);
    let mut cfg = quick_cfg("tri_accel_replica", 1);
    cfg.replicas = 2;
    let mut tr = Trainer::new(&e, cfg).unwrap();
    let r = tr.run_epoch(0).unwrap();
    assert!(r.train_loss.is_finite() && r.train_loss > 0.0);
    assert!(tr.controller.replica_active(), "elastic replica axis is live");
    assert!(tr.metrics.ctrl_windows > 0);
    // Roomy budget at this scale: the controller may restore/veto but
    // must never leave fewer than one replica live.
    assert!((1..=2).contains(&tr.controller.replicas()));
}

#[test]
fn pressure_rejects_bad_trace_and_method() {
    let e = engine();
    let tweak = |_: &mut Config| {};
    assert!(harness::pressure(&e, "tiny_cnn_c10", &["fp32"], &[0], "wobble", &tweak).is_err());
    let err = harness::pressure(&e, "tiny_cnn_c10", &["sgd"], &[0], "const", &tweak)
        .unwrap_err()
        .to_string();
    assert!(err.contains("registered methods"), "{err}");
}

#[test]
fn resume_rejects_method_mismatch() {
    let e = engine();
    let p = std::env::temp_dir()
        .join(format!("triaccel_policy_method_{}.bin", std::process::id()));
    let mut cfg = quick_cfg("fp32", 3);
    cfg.t_curv = 0;
    let mut tr = Trainer::new(&e, cfg).unwrap();
    for _ in 0..4 {
        tr.step().unwrap();
    }
    tr.save_checkpoint(&p).unwrap();

    let mut other = Trainer::new(&e, quick_cfg("greedy_batch", 3)).unwrap();
    let err = other.resume_from(&p).unwrap_err().to_string();
    assert!(err.contains("trained with method `fp32`"), "{err}");
    assert!(err.contains("greedy_batch"), "{err}");

    // Same method resumes fine.
    let mut cfg2 = quick_cfg("fp32", 3);
    cfg2.t_curv = 0;
    let mut same = Trainer::new(&e, cfg2).unwrap();
    assert_eq!(same.resume_from(&p).unwrap(), 4);
    std::fs::remove_file(&p).ok();
}

#[test]
fn restore_rejects_graph_digest_mismatch() {
    let e = engine();
    let p = std::env::temp_dir()
        .join(format!("triaccel_policy_digest_{}.bin", std::process::id()));
    let mut cfg = quick_cfg("fp32", 0);
    cfg.t_curv = 0;
    let tr = Trainer::new(&e, cfg).unwrap();
    tr.save_checkpoint(&p).unwrap();
    let mut ckpt = tri_accel::checkpoint::Checkpoint::load(&p).unwrap();
    assert_ne!(ckpt.graph_digest, 0, "v3 checkpoints carry the digest");
    ckpt.graph_digest ^= 1; // "the model definition changed"
    let mut cfg2 = quick_cfg("fp32", 0);
    cfg2.t_curv = 0;
    let mut tr2 = Trainer::new(&e, cfg2).unwrap();
    let err = tr2.session.restore(&ckpt).unwrap_err().to_string();
    assert!(err.contains("graph/geometry changed"), "{err}");
    std::fs::remove_file(&p).ok();
}

#[test]
fn trace_plumbs_from_config_into_the_run() {
    // `mem_trace` on the config reaches the simulator: squeezing the
    // budget to 1% mid-run must surface as OOM events for a static
    // method, where the constant trace records none.
    let e = engine();
    let run = |trace: &str| {
        let mut cfg = quick_cfg("amp_static", 0);
        cfg.steps_per_epoch = Some(12);
        cfg.mem_trace = trace.to_string();
        let mut tr = Trainer::new(&e, cfg).unwrap();
        tr.run_epoch(0).unwrap();
        tr.metrics.oom_events
    };
    assert_eq!(run("const"), 0, "fits the full budget");
    assert!(run("step:0.01@6") > 0, "squeezed budget must OOM");
}

/// Steps on which a *fixed* footprint OOMs under a scenario at base
/// budget `base_gb`: with `t_curv = 0` and zero noise the trainer
/// charges exactly one accounting call per step, and both sides of
/// the comparison use the same floats — so for a static method the
/// expected OOM count is closed-form, no tolerance needed.
fn expected_static_ooms(kind: ScenarioKind, steps: u64, base_gb: f64, footprint_gb: f64) -> u64 {
    (0..steps).filter(|&s| footprint_gb > base_gb * kind.factor(s)).count() as u64
}

#[test]
fn scenario_library_ooms_static_methods_exactly_and_elastic_methods_shed() {
    // Calibrate from the simulator: `amp_static` runs uniform 2-byte
    // precision at a fixed B=64, so its footprint is the BF16 usage at
    // 64. The headroom per scenario places the squeeze: 1.2 clears the
    // spike/frag plateaus (dips to 0.45/0.3 and the 0.595 ratchet tail
    // bite), 1.05 lets the leak's gentle decline bite by step 12.
    let e = engine();
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    let u64gb = sim.usage(64, &codes, false).total_gb;

    // (scenario, steps, headroom, strict): `strict` demands the elastic
    // method OOM strictly less — true for persistent squeezes, where
    // one shed absorbs the rest of the run; spike's 3-step bursts can
    // cost the elastic ladder an OOM per burst step, so only `<=` is
    // guaranteed there (the shed/recover asserts do the separating).
    let cases = [
        (ScenarioKind::Spike, 30u64, 1.2, false),
        (ScenarioKind::Frag, 42, 1.2, true),
        (ScenarioKind::Leak, 30, 1.05, true),
    ];
    for (kind, steps, headroom, strict) in cases {
        let base = u64gb * headroom;
        let want = expected_static_ooms(kind, steps, base, u64gb);
        assert!(want > 0, "{}: calibration must make the squeeze bite", kind.name());
        assert!(want < steps, "{}: the budget must also fit sometimes", kind.name());
        let tweak = move |cfg: &mut Config| {
            cfg.epochs = 1;
            cfg.steps_per_epoch = Some(steps as usize);
            cfg.train_examples = 4096;
            cfg.eval_examples = 128;
            cfg.batch_init = 64;
            cfg.t_ctrl = 3;
            cfg.t_curv = 0; // no probes: keep the footprint pure
            cfg.batch_cooldown = 2;
            cfg.warmup_epochs = 0;
            cfg.mem_budget_gb = base;
            cfg.mem_noise = 0.0;
        };
        let spec = format!("scenario:{}", kind.name());
        let rows = harness::pressure(
            &e,
            "tiny_cnn_c10",
            &["amp_static", "greedy_batch"],
            &[0],
            &spec,
            &tweak,
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        let (stat, elastic) = (&rows[0], &rows[1]);
        assert_eq!(stat.method_key, "amp_static");
        assert_eq!(
            stat.oom_events,
            want,
            "{}: static OOM count must match the closed-form factor series",
            kind.name()
        );
        assert_eq!(stat.min_batch, 64, "{}: static method never sheds", kind.name());
        assert!(elastic.min_batch < 64, "{}: elastic method must shed", kind.name());
        if strict {
            assert!(
                elastic.oom_events < stat.oom_events,
                "{}: shedding must beat ooming ({} vs {})",
                kind.name(),
                elastic.oom_events,
                stat.oom_events
            );
        } else {
            assert!(
                elastic.oom_events <= stat.oom_events,
                "{}: shedding must never oom more than static ({} vs {})",
                kind.name(),
                elastic.oom_events,
                stat.oom_events
            );
        }
        assert!(elastic.acc.mean().is_finite());
    }
}

#[test]
fn spike_scenario_sheds_and_recovers_the_batch() {
    // Between spike bursts the budget returns to 1.0, so an elastic
    // method must climb back: the batch trace has to show a shed below
    // the initial rung *and* a final rung above its own minimum.
    let e = engine();
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    let base = sim.usage(64, &codes, false).total_gb * 1.2;

    let mut cfg = quick_cfg("greedy_batch", 0);
    cfg.batch_init = 64;
    cfg.steps_per_epoch = Some(30);
    cfg.train_examples = 4096;
    cfg.t_ctrl = 3;
    cfg.t_curv = 0;
    cfg.batch_cooldown = 2;
    cfg.mem_budget_gb = base;
    cfg.mem_trace = "scenario:spike".to_string();
    let mut tr = Trainer::new(&e, cfg).unwrap();
    tr.run_epoch(0).unwrap();
    let min_b = tr.metrics.batch_trace.iter().map(|&(_, b)| b).min().unwrap();
    let (_, last_b) = *tr.metrics.batch_trace.last().unwrap();
    assert!(min_b < 64, "the bursts must force a shed, trace {:?}", tr.metrics.batch_trace);
    assert!(
        last_b > min_b,
        "the budget returns between bursts, so the ladder must climb back (min {min_b}, \
         final {last_b})"
    );
}

#[test]
fn leak_scenario_sheds_replicas_before_any_oom() {
    // The replica twin of the ramp test above, driven by the named
    // scenario: the leak declines 0.4%/step — three times gentler than
    // that ramp — so the replica controller always sheds at a window
    // before the live aggregate outgrows the budget. Sized so the leak
    // bottoms out where only a reduced replica set is sustainable.
    let e = Engine::native_replicated(4, 1);
    let entry = e.manifest.model("tiny_cnn_c10").unwrap().clone();
    let mut sim = VramSim::new(&entry, 1e9, 0.0, 0);
    let codes = vec![BF16; entry.num_layers];
    sim.set_replicas(4);
    let u4 = sim.usage(64, &codes, false).total_gb;
    sim.set_replicas(2);
    let u2 = sim.usage(64, &codes, false).total_gb;
    let base = u4 * 1.25;
    // Run until the leak reaches the factor where 2 replicas sit at
    // ~85% occupancy (clamped above the scenario's 0.5 floor), plus a
    // tail to let the shed settle.
    let f_end = ((u2 / 0.85) / base).max(0.52);
    let steps = ((1.0 - f_end) / 0.004).ceil() as usize + 10;

    let mut cfg = quick_cfg("greedy_batch", 0); // pinned BF16: pure footprint
    cfg.replicas = 4;
    cfg.elastic_replicas = true;
    cfg.batch_init = 64;
    cfg.steps_per_epoch = Some(steps);
    cfg.train_examples = 16384;
    cfg.t_ctrl = 2;
    cfg.t_curv = 0;
    cfg.batch_cooldown = 2;
    cfg.mem_budget_gb = base;
    cfg.mem_trace = "scenario:leak".to_string();
    let mut tr = Trainer::new(&e, cfg).unwrap();
    tr.run_epoch(0).unwrap();
    assert_eq!(tr.metrics.oom_events, 0, "the leak is gentle: shedding pre-empts every OOM");
    assert!(tr.metrics.replica_decisions > 0, "the replica policy acted");
    assert!(
        tr.controller.replicas() < 4,
        "the leak persists, so the shed must too (live: {})",
        tr.controller.replicas()
    );
    assert!(tr.controller.replicas() >= 1);
}
