//! The policy subsystem — the paper's control plane (§3) decomposed
//! into three composable policy traits plus the closed loop that runs
//! them on the `T_ctrl` cadence.
//!
//! * [`PrecisionPolicy`] — §3.1: owns the per-layer precision codes.
//!   Canonical impls: [`PrecisionController`] (variance-EMA adaptive)
//!   and [`PinnedPrecision`] (the FP32 / static-AMP baselines).
//! * [`CurvaturePolicy`] — §3.2: probe scheduling, λ smoothing,
//!   per-layer LR scales, precision-promotion flags. Canonical impls:
//!   [`CurvatureScheduler`] (amortized power iteration) and
//!   [`NoCurvature`] (baselines / curvature-off ablation).
//! * [`BatchPolicy`] — §3.3: the batch size B(t) on the AOT bucket
//!   ladder. Canonical impls: [`BatchController`] (VRAM feedback) and
//!   [`FixedBatch`] (the static baselines, which keep B and OOM).
//! * [`plane::ControlPlane`] — §3.4: composes any policy triple (plus
//!   the shared [`LossScaler`]) and mediates their interdependencies.
//!   The trainer talks to it only through the observation/decision
//!   surface ([`plane::StepPlan`], [`plane::ControlDecision`]).
//! * [`registry`] — named method specs (`fp32`, `amp_static`,
//!   `tri_accel`, `tri_accel_nocurv`, `amp_dynamic`, `greedy_batch`,
//!   …) resolved at arg-parse time into a policy composition. The
//!   Table-2 ablation flags are re-expressed as registry compositions.
//!
//! Every policy is a pure state machine over scalars/vectors — no
//! backend types — and must round-trip through `export_state` /
//! `import_state` *mid-control-window*: importing a snapshot taken at
//! an arbitrary step leaves all subsequent decisions bit-identical
//! (property-tested in `tests/prop_policy.rs`). Exported state is
//! namespaced per policy (`policy/<name>/<field>`); imports fall back
//! to the pre-policy legacy keys (`precision/…`, `curvature/…`,
//! `batch/state`, `scaler/state`, `controller/windows`) so existing
//! checkpoints still load.

pub mod batch;
pub mod curvature;
pub mod plane;
pub mod precision;
pub mod registry;
pub mod replica;

pub use batch::{BatchController, BatchMove, FixedBatch};
pub use curvature::{CurvatureScheduler, NoCurvature};
pub use plane::{ControlDecision, ControlPlane, PolicyCounts, StepPlan};
pub use precision::{LossScaler, PinnedPrecision, PrecisionController};
pub use registry::MethodSpec;
pub use replica::{ReplicaController, ReplicaMove};

/// The historical name: the §3.4 unified controller is now the policy
/// plane. Kept as an alias so call sites and tests read either way.
pub type Controller = ControlPlane;

/// §3.1 precision policy: owns the per-layer precision codes p_l(t).
pub trait PrecisionPolicy {
    /// Stable id used to namespace checkpoint state (`policy/<name>/…`).
    fn name(&self) -> &'static str;
    /// Per-step gradient-variance ingest (cheap; every step).
    fn observe(&mut self, grad_var: &[f32]);
    /// Recompute codes on the `T_ctrl` cadence; true if any changed.
    fn control_window(&mut self) -> bool;
    /// §3.2 promotion: pin layer `l` to FP32. Returns true if the
    /// policy honors promotions (adaptive), false if it ignores them.
    fn promote(&mut self, l: usize) -> bool;
    /// Does this policy move codes in response to observations? The
    /// plane gates the curvature→precision coupling on this.
    fn adaptive(&self) -> bool;
    fn codes(&self) -> &[i32];
    fn num_layers(&self) -> usize;
    /// Telemetry: code changes applied so far.
    fn transitions(&self) -> u64;
    /// Telemetry: per-layer variance estimates, if the policy keeps
    /// any (empty for pinned policies).
    fn variances(&self) -> Vec<f64> {
        Vec::new()
    }
    /// Telemetry: the (τ_low, τ_high) thresholds, if the policy uses
    /// them.
    fn thresholds(&self) -> Option<(f64, f64)> {
        None
    }
    fn export_state(&self) -> Vec<(String, Vec<f64>)>;
    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()>;
}

/// §3.2 curvature policy: probe cadence and consumption of λ.
pub trait CurvaturePolicy {
    fn name(&self) -> &'static str;
    /// Does this policy probe at all? (Gates probe memory accounting.)
    fn active(&self) -> bool;
    /// Should the trainer run a curvature probe at `step`?
    fn due(&self, step: u64) -> bool;
    /// Ingest per-layer Rayleigh quotients; returns layers whose probe
    /// vectors must be reset (non-finite λ).
    fn observe(&mut self, lambdas: &[f32]) -> Vec<usize>;
    /// Per-layer LR scales; `num_layers` ones when inactive/cold.
    fn lr_scales(&self, num_layers: usize) -> Vec<f32>;
    /// Layers flagged for precision promotion this window.
    fn promotions(&self) -> Vec<usize>;
    /// Telemetry: probes ingested so far.
    fn firings(&self) -> u64;
    /// Telemetry: smoothed per-layer λ estimates (empty when off).
    fn lambdas(&self) -> Vec<f64> {
        Vec::new()
    }
    fn export_state(&self) -> Vec<(String, Vec<f64>)>;
    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()>;
}

/// §3.3 batch policy: B(t) on the bucket ladder.
pub trait BatchPolicy {
    fn name(&self) -> &'static str;
    /// Does B(t) respond to memory pressure?
    fn elastic(&self) -> bool;
    /// One §3.3 decision (`fits` is the predictive OOM veto).
    fn update(
        &mut self,
        step: u64,
        mem_used: f64,
        mem_max: f64,
        fits: &mut dyn FnMut(usize) -> bool,
    ) -> BatchMove;
    /// Emergency shrink on an actual OOM signal; true if B moved.
    fn force_shrink(&mut self, step: u64) -> bool;
    fn current(&self) -> usize;
    /// Telemetry: moves + vetoes decided so far.
    fn decisions(&self) -> u64;
    /// The bucket ladder B(t) can live on (a fixed policy's ladder is
    /// the single bucket it holds).
    fn ladder(&self) -> Vec<usize> {
        vec![self.current()]
    }
    fn export_state(&self) -> Vec<(String, Vec<f64>)>;
    fn import_state(&mut self, kv: &[(String, Vec<f64>)]) -> anyhow::Result<()>;
}

/// Find a named state vector, trying keys in order (first the policy's
/// namespaced key, then the pre-policy legacy key).
pub(crate) fn ckpt_lookup<'a>(
    kv: &'a [(String, Vec<f64>)],
    keys: &[&str],
) -> anyhow::Result<&'a Vec<f64>> {
    ckpt_lookup_opt(kv, keys)
        .ok_or_else(|| anyhow::anyhow!("checkpoint missing `{}`", keys[0]))
}

/// Optional variant of [`ckpt_lookup`].
pub(crate) fn ckpt_lookup_opt<'a>(
    kv: &'a [(String, Vec<f64>)],
    keys: &[&str],
) -> Option<&'a Vec<f64>> {
    for key in keys {
        if let Some((_, v)) = kv.iter().find(|(k, _)| k == key) {
            return Some(v);
        }
    }
    None
}
