//! The paper's contribution: the unified Tri-Accel control loop (§3.4)
//! and its three interlocking controllers.
//!
//! * [`precision`] — §3.1 precision-adaptive updates: per-layer EMA of
//!   gradient variance → {FP16, BF16, FP32} codes, plus dynamic loss
//!   scaling for the FP16 leg.
//! * [`curvature`] — §3.2 sparse second-order signals: amortized power
//!   iteration scheduling, per-layer step-size scaling
//!   `η_l = η₀ / (1 + α·λ_max)`, and precision promotion.
//! * [`batch`] — §3.3 memory-elastic batch scaling: the VRAM feedback
//!   controller snapped to the AOT bucket ladder.
//! * [`control`] — §3.4 the closed loop that wires them together on a
//!   `T_ctrl` cadence.
//!
//! All controllers are pure state machines over scalars/vectors — no XLA
//! types — so they are unit- and property-testable in isolation; the
//! trainer (`crate::train`) feeds them measurements from the runtime and
//! the VRAM simulator.

pub mod batch;
pub mod control;
pub mod curvature;
pub mod precision;

pub use batch::BatchController;
pub use control::{ControlDecision, Controller};
pub use curvature::CurvatureScheduler;
pub use precision::{LossScaler, PrecisionController};

/// Find a named state vector in a checkpoint's controller section.
pub(crate) fn ckpt_lookup<'a>(
    kv: &'a [(String, Vec<f64>)],
    name: &str,
) -> anyhow::Result<&'a Vec<f64>> {
    kv.iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v)
        .ok_or_else(|| anyhow::anyhow!("checkpoint missing `{name}`"))
}
