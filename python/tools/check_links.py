#!/usr/bin/env python3
"""Markdown link checker for the docs CI job (stdlib only).

Usage: check_links.py <file-or-dir> [<file-or-dir> ...]

Walks the given markdown files (directories are searched for *.md) and
verifies that every relative link target exists on disk, resolved
against the linking file's directory. External schemes (http/https/
mailto) and pure in-page anchors (#...) are skipped; a `#fragment` on
a relative link is stripped before the existence check. Exits non-zero
listing every broken link.
"""

import os
import re
import sys

# [text](target) — ignores images' leading `!` (same target rules) and
# skips code spans line-wise (good enough for these docs).
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_FENCE_RE = re.compile(r"^\s*(```|~~~)")


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, names in os.walk(p):
                for n in sorted(names):
                    if n.endswith(".md"):
                        yield os.path.join(root, n)
        else:
            yield p


def check_file(path):
    broken = []
    in_fence = False
    with open(path, encoding="utf-8") as f:
        for ln, line in enumerate(f, 1):
            if CODE_FENCE_RE.match(line):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
                if not os.path.exists(resolved):
                    broken.append((ln, target, resolved))
    return broken


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    total = 0
    checked = 0
    for path in md_files(argv[1:]):
        checked += 1
        for ln, target, resolved in check_file(path):
            print(f"{path}:{ln}: broken link `{target}` -> {resolved}")
            total += 1
    print(f"checked {checked} markdown file(s): {total} broken link(s)")
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
