//! Adaptive-behaviour figure bench (DESIGN.md F1): the abstract's
//! "efficiency gradually improving over the course of training" series
//! plus the §4.2 effective-batch-size trace, for one Tri-Accel run.
//!
//! Env knobs: FIG_STEPS, FIG_EPOCHS, FIG_MODEL, FIG_SEED.

use tri_accel::harness;
use tri_accel::runtime::Engine;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let engine = Engine::native();
    let steps = env_usize("FIG_STEPS", 12);
    let epochs = env_usize("FIG_EPOCHS", 4);
    let seed = env_usize("FIG_SEED", 0) as u64;
    let model = std::env::var("FIG_MODEL").unwrap_or_else(|_| "tiny_cnn_c10".into());

    println!("== bench fig_adaptive — {model}, Tri-Accel, seed {seed} ==");
    let t = harness::fig_adaptive(&engine, &model, seed, &harness::quick_budget(steps, epochs))
        .expect("fig run");

    println!("{:>5} {:>10}  {:>18}", "epoch", "eff_score", "fp16/bf16/fp32");
    for ((e, s), (_, f16, b16, f32_)) in t.epoch_eff.iter().zip(&t.mix_trace) {
        let bar = "#".repeat((s * 2.0).min(60.0) as usize);
        println!("{e:>5} {s:>10.3}  {f16:>5.2}/{b16:.2}/{f32_:.2}  {bar}");
    }

    println!("\nbatch-size trace (step → B):");
    for (st, b) in &t.batch_trace {
        println!("  {st:>6} → {b}");
    }

    // Shape check: late-training efficiency ≥ early (the adaptive claim).
    if t.epoch_eff.len() >= 2 {
        let early = t.epoch_eff[0].1;
        let late = t.epoch_eff.last().unwrap().1;
        println!(
            "\nshape: efficiency trend {} (early {early:.3} → late {late:.3}; paper: gradually improving)",
            if late >= early { "OK" } else { "MISS" }
        );
    }
}
