//! Deterministic report artifacts rendered from the grid ledger alone.
//!
//! `render` turns a *complete* ledger into the paper artifacts for its
//! grid kind — `table1.md` / `table2.md` / `pressure.md` — plus a
//! `BENCH_grid.json` summary of modeled time and policy-decision
//! counts. Every value comes from the persisted per-seed results
//! (JSON-roundtripped, aggregated in fixed job-key order) and wall
//! clock is deliberately excluded, so the artifacts are byte-identical
//! across `--jobs` widths, kills-and-resumes, and machines: they diff
//! cleanly across PRs. Per-job wall seconds stay in `ledger.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::harness::{self, CellResult, PressureCell, SeedResult};
use crate::util::bench::BenchReport;
use crate::util::json::Json;

use super::ledger::{CellMeta, Ledger};

/// Render the report artifacts for a complete ledger into `grid_dir`;
/// returns the paths written. Errors if any job is missing (resume the
/// grid first).
pub fn render(grid_dir: &Path, led: &Ledger) -> Result<Vec<PathBuf>> {
    let cells = led.cell_results()?;
    let mut artifacts = Vec::new();
    let md = match led.kind.as_str() {
        "table1" => Some(("table1.md", table1_md(led)?)),
        "table2" => Some(("table2.md", table2_md(led)?)),
        "pressure" => Some(("pressure.md", pressure_md(led)?)),
        "fig" => None,
        other => anyhow::bail!("unknown grid kind `{other}` in ledger"),
    };
    if let Some((name, text)) = md {
        let path = grid_dir.join(name);
        std::fs::write(&path, text).with_context(|| format!("writing {}", path.display()))?;
        artifacts.push(path);
    }
    let bench = bench_grid(led, &cells)?;
    let bench_path = grid_dir.join("BENCH_grid.json");
    bench.write(&bench_path).with_context(|| format!("writing {}", bench_path.display()))?;
    artifacts.push(bench_path);
    Ok(artifacts)
}

/// Render a *partial* report for a grid with quarantined jobs: cells
/// whose every job completed render as normal rows; cells blocked by a
/// quarantined job are listed with the failure that quarantined them.
/// The file is clearly marked PARTIAL and `BENCH_grid.json` is *not*
/// written — the diffable summary only ever describes complete grids.
/// Rerunning the grid command retries the quarantined jobs and, once
/// they pass, overwrites this file with the full report.
pub fn render_partial(
    grid_dir: &Path,
    led: &Ledger,
    quarantined: &[super::Quarantine],
) -> Result<Vec<PathBuf>> {
    let name = match led.kind.as_str() {
        "table1" => "table1.md",
        "table2" => "table2.md",
        "pressure" => "pressure.md",
        "fig" => "fig.md",
        other => anyhow::bail!("unknown grid kind `{other}` in ledger"),
    };
    let mut whole = Vec::new();
    let mut blocked = Vec::new();
    for c in &led.cells {
        if c.job_keys.iter().all(|k| led.entries.contains_key(k)) {
            whole.push(c.clone());
        } else {
            blocked.push(c.clone());
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "# {} — grid `{}` — PARTIAL ({} of {} cells quarantined)\n\n",
        led.kind,
        led.grid_id,
        blocked.len(),
        led.cells.len()
    ));
    out.push_str(
        "Some jobs exhausted their supervisor retries and were quarantined \
         (see `docs/FAULTS.md`). Completed cells are reported below; rerun \
         the same grid command to retry the quarantined jobs and render the \
         full report.\n\n",
    );
    if !whole.is_empty() {
        let reduced = Ledger { cells: whole, ..led.clone() };
        let rows = cell_rows(&reduced)?;
        out.push_str("## Completed cells\n\n");
        out.push_str("| Model | Method | Acc (%) | Time (s) | VRAM (GB) | Score |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for r in &rows {
            out.push_str(&format!(
                "| {} | {} | {:.1} ± {:.2} | {:.2} ± {:.2} | {:.4} ± {:.4} | {:.2} |\n",
                r.model_key,
                r.label,
                r.acc.mean(),
                r.acc.std(),
                r.modeled_s.mean(),
                r.modeled_s.std(),
                r.peak_gb.mean(),
                r.peak_gb.std(),
                r.score.mean(),
            ));
        }
        out.push('\n');
    }
    out.push_str("## Quarantined cells\n\n");
    for c in &blocked {
        out.push_str(&format!("- **{}** / {} (`{}`)\n", c.model, c.label, c.method_key));
        for k in &c.job_keys {
            if let Some(q) = quarantined.iter().find(|q| &q.key == k) {
                out.push_str(&format!(
                    "  - `{}`: quarantined after {} attempt(s): {}\n",
                    q.key, q.attempts, q.error
                ));
            } else if !led.entries.contains_key(k) {
                out.push_str(&format!("  - `{k}`: not yet run\n"));
            }
        }
    }
    let path = grid_dir.join(name);
    std::fs::write(&path, out).with_context(|| format!("writing {}", path.display()))?;
    Ok(vec![path])
}

/// Aggregate a complete ledger into Table rows (one [`CellResult`]
/// per cell, canonical order). This is the *only* reduction path: the
/// markdown artifacts and the CLI's stdout tables both call it, so
/// the two can never disagree.
pub fn cell_rows(led: &Ledger) -> Result<Vec<CellResult>> {
    led.cells
        .iter()
        .zip(led.cell_results()?.iter())
        .map(|(meta, rs)| harness::aggregate_cell(&meta.model, &meta.label, rs))
        .collect()
}

/// Aggregate a complete ledger into pressure-sweep rows (shared by
/// `pressure.md` and the CLI's stdout table).
pub fn pressure_rows(led: &Ledger) -> Result<Vec<PressureCell>> {
    led.cells
        .iter()
        .zip(led.cell_results()?.iter())
        .map(|(meta, rs)| harness::aggregate_pressure(&meta.method_key, &meta.label, rs))
        .collect()
}

fn table1_md(led: &Ledger) -> Result<String> {
    let rows = cell_rows(led)?;
    let mut out = String::new();
    out.push_str(&format!("# Table 1 — grid `{}`\n\n", led.grid_id));
    out.push_str(
        "Rendered deterministically from `ledger.json`: per-seed results are \
         aggregated in fixed job-key order, wall clock is excluded (see \
         `docs/TELEMETRY.md`). Time is modeled accelerator seconds per epoch.\n\n",
    );
    out.push_str("| Model | Method | Acc (%) | Time (s) | VRAM (GB) | Score |\n");
    out.push_str("|---|---|---|---|---|---|\n");
    for r in &rows {
        out.push_str(&format!(
            "| {} | {} | {:.1} ± {:.2} | {:.2} ± {:.2} | {:.4} ± {:.4} | {:.2} |\n",
            r.model_key,
            r.label,
            r.acc.mean(),
            r.acc.std(),
            r.modeled_s.mean(),
            r.modeled_s.std(),
            r.peak_gb.mean(),
            r.peak_gb.std(),
            r.score.mean(),
        ));
    }
    // Headline deltas for full (FP32, AMP, Tri-Accel) triples.
    let mut headlines = String::new();
    for chunk in rows.chunks(3) {
        if chunk.len() == 3
            && chunk[0].model_key == chunk[2].model_key
            && chunk[0].label == "FP32 Baseline"
            && chunk[2].label == "Tri-Accel"
        {
            headlines.push_str(&format!(
                "- **{}** — {}\n",
                chunk[0].model_key,
                harness::headline(&chunk[0], &chunk[2])
            ));
        }
    }
    if !headlines.is_empty() {
        out.push_str("\n## Headline deltas\n\n");
        out.push_str(&headlines);
    }
    Ok(out)
}

fn table2_md(led: &Ledger) -> Result<String> {
    let rows = cell_rows(led)?;
    anyhow::ensure!(!rows.is_empty(), "table2 grid has no rows");
    let model = &rows[0].model_key;
    let base = rows[0].peak_gb.mean();
    let mut out = String::new();
    out.push_str(&format!("# Table 2 ablation — {model} — grid `{}`\n\n", led.grid_id));
    out.push_str("| Configuration | VRAM (GB) | Reduction |\n|---|---|---|\n");
    for (i, r) in rows.iter().enumerate() {
        let red = if i == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", 100.0 * (base - r.peak_gb.mean()) / base)
        };
        out.push_str(&format!("| {} | {:.4} | {} |\n", r.label, r.peak_gb.mean(), red));
    }
    Ok(out)
}

fn pressure_md(led: &Ledger) -> Result<String> {
    let rows = pressure_rows(led)?;
    anyhow::ensure!(!rows.is_empty(), "pressure grid has no rows");
    let model = &led.cells[0].model;
    let trace = &led.cells[0].trace;
    let seeds = led.cells[0].seeds.len();
    let mut out = String::new();
    out.push_str(&format!("# VRAM pressure — {model} — grid `{}`\n\n", led.grid_id));
    out.push_str(&format!(
        "Budget trace `{trace}`, {seeds} seed(s). Static methods accumulate \
         simulated OOMs; elastic methods shed data-parallel replicas \
         (`R_min`, the numerics-free lever) and batch buckets (`B_min`) \
         and survive.\n\n"
    ));
    // A named scenario gets its one-line adversarial description so
    // the artifact is self-explaining (library: docs/MEMORY.md).
    if let Some(name) = trace.strip_prefix("scenario:") {
        if let Ok(k) = crate::memsim::scenarios::ScenarioKind::parse(name) {
            out.push_str(&format!("Scenario `{}`: {}.\n\n", k.name(), k.describe()));
        }
    }
    out.push_str(
        "| Method | Acc (%) | VRAM (GB) | OOMs | B_min | R_min | B decs | R decs | Score |\n",
    );
    out.push_str("|---|---|---|---|---|---|---|---|---|\n");
    for r in &rows {
        let min_b = if r.min_batch == usize::MAX { 0 } else { r.min_batch };
        let min_r = if r.min_replicas == usize::MAX { 0 } else { r.min_replicas };
        out.push_str(&format!(
            "| {} | {:.1} ± {:.2} | {:.4} | {} | {} | {} | {} | {} | {:.2} |\n",
            r.label,
            r.acc.mean(),
            r.acc.std(),
            r.peak_gb.mean(),
            r.oom_events,
            min_b,
            min_r,
            r.batch_decisions,
            r.replica_decisions,
            r.score.mean(),
        ));
    }
    Ok(out)
}

/// The `BENCH_grid.json` summary: one row per cell with modeled-time
/// aggregates and summed policy-decision counters. Wall clock is
/// excluded by design (it lives per job in `ledger.json`), so this
/// file is bit-identical across reruns, resumes, and `--jobs` widths.
fn bench_grid(led: &Ledger, cells: &[Vec<SeedResult>]) -> Result<BenchReport> {
    let mut rep = BenchReport::new("grid");
    rep.meta_str("grid_id", &led.grid_id);
    rep.meta_str("kind", &led.kind);
    rep.meta_num("schema", led.schema as f64);
    rep.meta_num("jobs_total", cells.iter().map(Vec::len).sum::<usize>() as f64);
    for (meta, rs) in led.cells.iter().zip(cells.iter()) {
        rep.push_json(bench_row(meta, rs)?);
    }
    Ok(rep)
}

fn bench_row(meta: &CellMeta, rs: &[SeedResult]) -> Result<Json> {
    let cell = harness::aggregate_cell(&meta.model, &meta.label, rs)?;
    let press = harness::aggregate_pressure(&meta.method_key, &meta.label, rs)?;
    let mut m = BTreeMap::new();
    m.insert("name".into(), Json::Str(format!("{}/{}", meta.model, meta.method_key)));
    m.insert("label".into(), Json::Str(meta.label.clone()));
    m.insert("trace".into(), Json::Str(meta.trace.clone()));
    m.insert("seeds".into(), Json::Num(rs.len() as f64));
    let mut num = |k: &str, v: f64| {
        m.insert(k.to_string(), Json::Num(v));
    };
    num("acc_mean", cell.acc.mean());
    num("acc_std", cell.acc.std());
    num("modeled_s_mean", cell.modeled_s.mean());
    num("modeled_s_std", cell.modeled_s.std());
    num("peak_gb_mean", cell.peak_gb.mean());
    num("score_mean", cell.score.mean());
    num("oom_events", press.oom_events as f64);
    num("batch_decisions", press.batch_decisions as f64);
    num("min_batch", press.min_batch as f64);
    num("replica_decisions", press.replica_decisions as f64);
    num("min_replicas", press.min_replicas as f64);
    let sum = |f: fn(&SeedResult) -> u64| rs.iter().map(f).sum::<u64>() as f64;
    num("ctrl_windows", sum(|r| r.ctrl_windows));
    num("precision_transitions", sum(|r| r.precision_transitions));
    num("curv_firings", sum(|r| r.curv_firings));
    Ok(Json::Obj(m))
}

/// The adaptive-behaviour series of a `fig` grid, reconstructed from
/// its telemetry stream alone (`events/<job>.jsonl`): per-epoch
/// efficiency/precision-mix rows plus the deduplicated (step, batch)
/// trace — proof the event stream carries everything the figure needs.
#[derive(Debug, Clone)]
pub struct FigSeries {
    /// (epoch, efficiency score).
    pub epoch_eff: Vec<(usize, f64)>,
    /// (epoch, fp16 frac, bf16 frac, fp32 frac).
    pub mix_trace: Vec<(usize, f64, f64, f64)>,
    /// (step, batch size) at every change.
    pub batch_trace: Vec<(u64, usize)>,
}

/// Read a `fig` grid's series back out of its telemetry JSONL.
pub fn fig_series(grid_dir: &Path, led: &Ledger) -> Result<FigSeries> {
    anyhow::ensure!(led.kind == "fig", "fig series need a fig grid, got `{}`", led.kind);
    let key = led
        .cells
        .first()
        .and_then(|c| c.job_keys.first())
        .context("fig ledger has no job")?;
    let path = grid_dir.join("events").join(format!("{key}.jsonl"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut out = FigSeries {
        epoch_eff: Vec::new(),
        mix_trace: Vec::new(),
        batch_trace: Vec::new(),
    };
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let ev = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("{}:{}: {e}", path.display(), ln + 1))?;
        match ev.req("event")?.as_str() {
            Some("epoch") => {
                let epoch = ev.req("epoch")?.as_usize().context("epoch index")?;
                out.epoch_eff
                    .push((epoch, ev.req("eff_score")?.as_f64().context("eff_score")?));
                out.mix_trace.push((
                    epoch,
                    ev.req("fp16_frac")?.as_f64().context("fp16_frac")?,
                    ev.req("bf16_frac")?.as_f64().context("bf16_frac")?,
                    ev.req("fp32_frac")?.as_f64().context("fp32_frac")?,
                ));
            }
            Some("step") => {
                let step = ev.req("step")?.as_i64().context("step index")? as u64;
                let b = ev.req("batch")?.as_usize().context("step batch")?;
                if out.batch_trace.last().map(|&(_, pb)| pb) != Some(b) {
                    out.batch_trace.push((step, b));
                }
            }
            _ => {}
        }
    }
    anyhow::ensure!(!out.epoch_eff.is_empty(), "no epoch events in {}", path.display());
    Ok(out)
}
