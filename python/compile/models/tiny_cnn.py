"""tiny_cnn — 3-block CNN for CIFAR (fast path for CI, quickstart, and the
Rust integration tests). 4 precision layers: conv1..conv3 + dense head.
~25k params, so full train-step artifacts lower in seconds.
"""

from __future__ import annotations

import jax.nn

from . import common as C

NAME = "tiny_cnn"


def make_forward(num_classes: int):
    def forward(store: C.Store, x):
        x = C.conv2d(store, "conv1", x, 16, kernel=3)
        x = C.batchnorm(store, "bn1", x)
        x = jax.nn.relu(x)
        x = C.max_pool(x)  # 16x16
        x = C.conv2d(store, "conv2", x, 32, kernel=3)
        x = C.batchnorm(store, "bn2", x)
        x = jax.nn.relu(x)
        x = C.max_pool(x)  # 8x8
        x = C.conv2d(store, "conv3", x, 64, kernel=3)
        x = C.batchnorm(store, "bn3", x)
        x = jax.nn.relu(x)
        x = C.global_avg_pool(x)
        return C.dense(store, "head", x, num_classes)

    return forward


def build(num_classes: int = 10, seed: int = 0) -> C.Model:
    return C.build_model(NAME, num_classes, make_forward(num_classes), seed=seed)
